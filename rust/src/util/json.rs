//! Minimal JSON parser/serializer (offline substitute for `serde_json`).
//!
//! Used for `artifacts/meta.json` (the AOT calling-convention contract),
//! experiment configs, and metric report emission. Supports the full JSON
//! grammar minus exotic escapes (`\uXXXX` surrogate pairs are handled).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- accessors ----------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access, e.g. `j.path(&["policy","batch"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- construction helpers ------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // -- parsing -------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("bad low surrogate"));
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(c) => {
                    // copy a full UTF-8 sequence
                    let start = self.pos;
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos += len;
                    let chunk = self
                        .b
                        .get(start..self.pos)
                        .ok_or_else(|| self.err("truncated utf8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let chunk = self
            .b
            .get(self.pos..self.pos + 4)
            .ok_or_else(|| self.err("truncated \\u escape"))?;
        let s = std::str::from_utf8(chunk).map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// -- serialization -----------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": false}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path(&["a"]).unwrap().as_arr().unwrap()[1]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(j.get("c").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let j = Json::parse(r#""a\n\t\"\\ é 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\n\t\"\\ é 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"o":{"k":-7}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn accessor_types_strict() {
        let j = Json::parse(r#"{"n": 1.5, "i": 3}"#).unwrap();
        assert_eq!(j.get("n").unwrap().as_u64(), None);
        assert_eq!(j.get("i").unwrap().as_u64(), Some(3));
        assert_eq!(j.get("i").unwrap().as_str(), None);
    }
}
