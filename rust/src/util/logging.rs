//! Leveled logger (offline substitute for `tracing`). Writes to stderr;
//! level set via `ARL_LOG` env var (error|warn|info|debug|trace) or
//! programmatically. Cheap when disabled: level check is one atomic load.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info
static INITED: AtomicU8 = AtomicU8::new(0);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
    INITED.store(1, Ordering::Relaxed);
}

pub fn init_from_env() {
    if INITED.swap(1, Ordering::Relaxed) == 1 {
        return;
    }
    if let Ok(v) = std::env::var("ARL_LOG") {
        let l = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        };
        LEVEL.store(l as u8, Ordering::Relaxed);
    }
}

#[inline]
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {target}: {msg}");
    }
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
