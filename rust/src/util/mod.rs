//! From-scratch utility substrates for the fully-offline build environment.
//!
//! These replace the crates a networked project would pull in (see the note
//! in Cargo.toml): [`rng`] ↔ rand/rand_distr, [`json`] ↔ serde_json,
//! [`cli`] ↔ clap, [`logging`] ↔ tracing, [`error`] ↔ anyhow/thiserror.

pub mod cli;
pub mod error;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stopwatch;

/// Simple descriptive statistics over a slice (used everywhere in metrics).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// p in [0,100]; linear interpolation between order statistics.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi.min(sorted.len() - 1)] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_percentile() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }
}
