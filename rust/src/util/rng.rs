//! Deterministic PRNG + samplers (offline substitute for `rand`/`rand_distr`).
//!
//! xoshiro256++ — fast, well-tested generator; every simulation component
//! takes an explicit seed so whole cluster-scale experiments replay bit-for-
//! bit (the paper's traces are irreproducible; ours must not be).

/// SplitMix64: the seeding generator behind [`Rng::new`], public so the
/// scenario fuzzer can derive byte-identical specs from a bare `u64` seed
/// without dragging in the full xoshiro state. Any refactor here must keep
/// the output stream bit-identical — every golden trace depends on it.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform u64 in [lo, hi] inclusive. Modulo bias is negligible for the
    /// tiny ranges the fuzzer draws (≪ 2^32).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Bernoulli trial with probability `num / den`.
    pub fn chance(&mut self, num: u64, den: u64) -> bool {
        debug_assert!(num <= den && den > 0);
        self.next_u64() % den < num
    }

    /// Pick a random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        debug_assert!(!xs.is_empty());
        &xs[(self.next_u64() % xs.len() as u64) as usize]
    }
}

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via [`SplitMix64`] so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        if s.iter().all(|&x| x == 0) {
            s[0] = 1;
        }
        Rng { s }
    }

    /// Derive an independent child stream (for per-component RNGs).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    // -- distributions ------------------------------------------------------

    /// Exponential with the given mean (inter-arrival times).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal (Box–Muller, one value per call for simplicity).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos();
        mean + std * z
    }

    /// Log-normal parameterized by the *underlying* normal's mu/sigma.
    /// Action durations and LLM-generation times are long-tailed; the paper's
    /// Fig. 3(c)/(d) burstiness comes from exactly this family.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Pareto (heavy tail) with scale `xm` and shape `alpha`.
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = 1.0 - self.f64();
        xm / u.powf(1.0 / alpha)
    }

    /// Poisson via inversion (small lambda) / normal approx (large lambda).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            self.normal(lambda, lambda.sqrt()).max(0.0).round() as u64
        }
    }

    /// Zipf-like categorical over `n` items (invocation skew across services).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // Inverse-CDF over precomputable weights would be faster; n is small
        // everywhere we use this (≤ dozens of services).
        let total: f64 = (1..=n).map(|i| 1.0 / (i as f64).powf(s)).sum();
        let mut u = self.f64() * total;
        for i in 1..=n {
            u -= 1.0 / (i as f64).powf(s);
            if u <= 0.0 {
                return i - 1;
            }
        }
        n - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_matches_reference_mix() {
        // Rng::new used to inline this exact sequence; the extracted
        // SplitMix64 must reproduce it bit-for-bit or every golden trace
        // (and fuzz-seed corpus entry) silently re-rolls.
        for seed in [0u64, 1, 42, 0xDEAD_BEEF, u64::MAX] {
            let mut sm = SplitMix64::new(seed);
            let mut state = seed;
            for _ in 0..16 {
                state = state.wrapping_add(0x9E3779B97F4A7C15);
                let mut z = state;
                z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
                z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
                assert_eq!(sm.next_u64(), z ^ (z >> 31));
            }
        }
    }

    #[test]
    fn splitmix_range_and_pick_in_bounds() {
        let mut sm = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!((3..=9).contains(&sm.range(3, 9)));
            assert!([1u32, 2, 3].contains(sm.pick(&[1, 2, 3])));
        }
        assert_eq!(sm.range(5, 5), 5);
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelate() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Rng::new(11);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn exponential_mean_close() {
        let mut r = Rng::new(13);
        let mean = 4.0;
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.exponential(mean)).sum();
        assert!((sum / n as f64 - mean).abs() < 0.05 * mean);
    }

    #[test]
    fn normal_moments_close() {
        let mut r = Rng::new(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(2.0, 3.0)).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!((m - 2.0).abs() < 0.05, "mean {m}");
        assert!((v - 9.0).abs() < 0.3, "var {v}");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(19);
        for &lam in &[0.5, 5.0, 80.0] {
            let n = 50_000;
            let sum: u64 = (0..n).map(|_| r.poisson(lam)).sum();
            let m = sum as f64 / n as f64;
            assert!((m - lam).abs() < 0.1 * lam.max(1.0), "lam {lam} got {m}");
        }
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(23);
        let mut counts = [0u32; 12];
        for _ in 0..50_000 {
            counts[r.zipf(12, 1.1)] += 1;
        }
        assert!(counts[0] > counts[5]);
        assert!(counts[0] > counts[11]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(31);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
