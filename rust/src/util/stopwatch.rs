//! Wall-clock stopwatch — the single allowlisted real-time site.
//!
//! Everything the simulation decides runs on virtual [`crate::sim::SimTime`];
//! wall time exists only to report how long the simulator itself took
//! (scheduler hot-path counters, `run`/`scenario` wall lines, the bench
//! harness). Those eight timing blocks used to each call
//! `std::time::Instant::now()` directly; they now share this helper so the
//! determinism lint (`arl-tangram lint`, rule `wall-clock`) can allowlist
//! exactly one file. Wall time must never feed scheduling decisions or
//! serialized state — golden traces are virtual-time only.

use std::time::{Duration, Instant};

/// Started timer over the monotonic wall clock.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch { t0: Instant::now() }
    }

    /// Wall time elapsed since [`Stopwatch::start`].
    pub fn elapsed(&self) -> Duration {
        self.t0.elapsed()
    }

    /// Elapsed wall seconds (the common report unit).
    pub fn secs(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_is_monotonic() {
        let sw = Stopwatch::start();
        let a = sw.elapsed();
        let b = sw.elapsed();
        assert!(b >= a);
        assert!(sw.secs() >= 0.0);
    }
}
