//! Autoscaler system tests: the differential resource-hour/ACT harness on
//! the cold-start-storm pack, determinism of autoscaled runs, and the
//! `--against` A/B comparison path.
//!
//! The unit-level hysteresis/cold-start behaviour lives in
//! `src/autoscale/mod.rs`; these tests run the whole driver stack.

use arl_tangram::autoscale::{
    AutoscaleCfg, Autoscaler, LaneKey, PolicyKind, PoolClass, PoolPressure, ScaleCmd,
};
use arl_tangram::config::BackendKind;
use arl_tangram::lanes::CostModel;
use arl_tangram::scenario::{
    ab_compare, pack_by_name, parse_trace_file, run_scenario, summary_json, trace_file_contents,
    trace_pool_stats, TraceKind,
};

/// The A/B pair for one pack: (static outcome, autoscaled outcome).
fn ab_outcomes(
    pack: &str,
) -> (
    arl_tangram::scenario::ScenarioOutcome,
    arl_tangram::scenario::ScenarioOutcome,
    arl_tangram::scenario::ScenarioSpec,
    arl_tangram::scenario::ScenarioSpec,
) {
    let spec = pack_by_name(pack).unwrap();
    let mut auto_spec = spec.clone();
    auto_spec.autoscale = Some(AutoscaleCfg::default());
    let stat = run_scenario(&spec, BackendKind::Tangram).unwrap();
    let auto = run_scenario(&auto_spec, BackendKind::Tangram).unwrap();
    (stat, auto, spec, auto_spec)
}

#[test]
fn coldstart_storm_saves_resource_hours_at_act_parity() {
    // The acceptance differential: autoscaling the cold-start-storm pack
    // must save resource-hours vs the static run while staying within 10%
    // of its mean ACT, with full completion on both sides.
    let (stat, auto, spec, _) = ab_outcomes("coldstart-storm");
    let expected =
        spec.workloads_for(BackendKind::Tangram).len() * spec.batch * spec.steps as usize;
    assert_eq!(stat.metrics.trajectories.len(), expected);
    assert_eq!(auto.metrics.trajectories.len(), expected, "autoscaling lost trajectories");
    assert_eq!(auto.metrics.failed_actions(), 0, "autoscaling failed actions");

    // a static run never resizes, so it reports zero savings by definition
    assert!(stat.metrics.savings_vs_static().abs() < 1e-12);

    let savings = auto.metrics.savings_vs_static();
    assert!(savings > 0.0, "autoscaler saved nothing: {savings}");

    let (a, b) = (stat.metrics.mean_act(), auto.metrics.mean_act());
    assert!(a > 0.0);
    let drift = (b - a).abs() / a;
    assert!(
        drift <= 0.10,
        "mean ACT drifted {:.1}% (static {a:.2}s vs autoscaled {b:.2}s)",
        drift * 100.0
    );
}

#[test]
fn gpu_thrash_saves_resource_hours_at_act_parity() {
    // The PoolClass::Gpu acceptance differential: autoscaling the
    // gpu-thrash pack (teacher-sweep arrivals under cache-flush storms and
    // a provider-side GPU squeeze) must save aggregate resource-hours vs
    // the static run — with the `gpus` pool itself contributing — while
    // staying within 10% of its mean ACT, with full completion both sides.
    let (stat, auto, spec, _) = ab_outcomes("gpu-thrash");
    let expected =
        spec.workloads_for(BackendKind::Tangram).len() * spec.batch * spec.steps as usize;
    assert_eq!(stat.metrics.trajectories.len(), expected);
    assert_eq!(auto.metrics.trajectories.len(), expected, "autoscaling lost trajectories");
    assert_eq!(auto.metrics.failed_actions(), 0, "autoscaling failed actions");

    assert!(stat.metrics.savings_vs_static().abs() < 1e-12);
    let savings = auto.metrics.savings_vs_static();
    assert!(savings > 0.0, "autoscaler saved nothing: {savings}");

    // the GPU lane itself must be elastic, not just ride on CPU/API savings
    let (gpu_used, gpu_static) = auto.metrics.pool_unit_hours("gpus");
    assert!(gpu_static > 0.0);
    assert!(
        gpu_used < gpu_static,
        "gpus pool never scaled down: used {gpu_used} !< static {gpu_static}"
    );

    let (a, b) = (stat.metrics.mean_act(), auto.metrics.mean_act());
    assert!(a > 0.0);
    let drift = (b - a).abs() / a;
    assert!(
        drift <= 0.10,
        "mean ACT drifted {:.1}% (static {a:.2}s vs autoscaled {b:.2}s)",
        drift * 100.0
    );
}

#[test]
fn gpu_thrash_faults_compose_with_gpu_autoscaling() {
    // Driver-level mirror of the backend composition regression: the pack
    // injects gpu_cache_flush storms and a gpu_pool_scale flap+restore in
    // the middle of autoscaled GPU resizes — every injection must apply,
    // the run must complete, and the trace must carry gpus scale events.
    let spec = {
        let mut s = pack_by_name("gpu-thrash").unwrap();
        s.autoscale = Some(AutoscaleCfg::default());
        s
    };
    let outcome = run_scenario(&spec, BackendKind::Tangram).unwrap();
    let applied: Vec<bool> = outcome
        .events
        .iter()
        .filter_map(|e| match &e.kind {
            TraceKind::Inject { applied, .. } => Some(*applied),
            _ => None,
        })
        .collect();
    assert_eq!(applied.len(), spec.events.len());
    assert!(applied.iter().all(|&a| a), "tangram must honor flushes and GPU squeezes");
    let gpu_scales = outcome
        .events
        .iter()
        .filter(|e| matches!(&e.kind, TraceKind::Scale { pool, .. } if pool == "gpus"))
        .count();
    assert!(gpu_scales > 0, "no gpus scale decisions recorded");
    assert_eq!(outcome.metrics.failed_actions(), 0);
    assert_eq!(
        outcome.metrics.trajectories.len(),
        spec.workloads_for(BackendKind::Tangram).len() * spec.batch * spec.steps as usize
    );
}

#[test]
fn gpu_thrash_autoscaled_trace_records_and_replays() {
    use arl_tangram::scenario::replay_trace;
    let mut spec = pack_by_name("gpu-thrash").unwrap();
    spec.autoscale = Some(AutoscaleCfg::default());
    let outcome = run_scenario(&spec, BackendKind::Tangram).unwrap();
    let text = trace_file_contents(&spec, BackendKind::Tangram, &outcome);
    let recorded = parse_trace_file(&text).unwrap();
    assert_eq!(recorded.spec.autoscale, spec.autoscale);
    let report = replay_trace(&recorded).unwrap();
    assert!(
        report.identical,
        "gpu-thrash autoscaled replay diverged: {:?} {:?}",
        report.summary_diff, report.trace_divergences
    );
}

#[test]
fn autoscaled_runs_are_deterministic() {
    let spec = {
        let mut s = pack_by_name("coldstart-storm").unwrap();
        s.autoscale = Some(AutoscaleCfg::default());
        s
    };
    let first = run_scenario(&spec, BackendKind::Tangram).unwrap();
    let second = run_scenario(&spec, BackendKind::Tangram).unwrap();
    assert_eq!(
        summary_json(&first.metrics).to_string(),
        summary_json(&second.metrics).to_string(),
        "autoscaled summaries must be byte-identical"
    );
    assert_eq!(first.events, second.events, "autoscaled traces must be identical");
    // the autoscaler actually acted: scale events present in the trace
    let scales = first
        .events
        .iter()
        .filter(|e| matches!(e.kind, TraceKind::Scale { .. }))
        .count();
    assert!(scales > 0, "no scale decisions recorded");
}

#[test]
fn autoscaled_trace_records_and_replays() {
    // record → parse → replay must be byte-identical with the autoscale
    // config embedded in the spec (self-contained trace files)
    use arl_tangram::scenario::replay_trace;
    let mut spec = pack_by_name("teacher-sweep").unwrap();
    spec.autoscale = Some(AutoscaleCfg { policy: PolicyKind::Ewma, ..AutoscaleCfg::default() });
    let outcome = run_scenario(&spec, BackendKind::Tangram).unwrap();
    let text = trace_file_contents(&spec, BackendKind::Tangram, &outcome);
    let recorded = parse_trace_file(&text).unwrap();
    assert_eq!(recorded.spec.autoscale, spec.autoscale, "autoscale must survive the trace file");
    let report = replay_trace(&recorded).unwrap();
    assert!(
        report.identical,
        "autoscaled replay diverged: {:?} {:?}",
        report.summary_diff, report.trace_divergences
    );
}

#[test]
fn ab_compare_quantifies_the_savings() {
    let (stat, auto, spec, auto_spec) = ab_outcomes("coldstart-storm");
    let a = parse_trace_file(&trace_file_contents(&spec, BackendKind::Tangram, &stat)).unwrap();
    let b =
        parse_trace_file(&trace_file_contents(&auto_spec, BackendKind::Tangram, &auto)).unwrap();
    let report = ab_compare(&a, &b);
    assert!(!report.identical, "autoscaled vs static must diverge");
    assert!(!report.divergences.is_empty());
    assert!(!report.rows.is_empty());
    let cpu = report.rows.iter().find(|r| r.pool == "cpu_cores").unwrap();
    assert!(cpu.a.actions > 0);
    assert!(
        cpu.b.unit_hours < cpu.a.unit_hours,
        "autoscaled cpu unit-hours must shrink: {} !< {}",
        cpu.b.unit_hours,
        cpu.a.unit_hours
    );
    // the cost column prices unit-hours under the (default) rate card:
    // fewer core-hours ⇒ fewer dollars, and the delta is reported
    assert!(cpu.cost_a > 0.0);
    assert!(
        cpu.cost_b < cpu.cost_a,
        "autoscaled cpu dollars must shrink: {} !< {}",
        cpu.cost_b,
        cpu.cost_a
    );
    assert!(cpu.cost_delta().unwrap() < 0.0);
    // self-comparison is the identity
    let same = ab_compare(&a, &a);
    assert!(same.identical);
    assert!(same.divergences.is_empty());
}

#[test]
fn trace_pool_stats_integrates_provision_series() {
    // hand-built stream: 100 units for 100s, then 50 units for 100s
    use arl_tangram::scenario::TraceEvent;
    use arl_tangram::sim::SimTime;
    let ns = 1_000_000_000u64;
    let events = vec![
        TraceEvent {
            at: SimTime(0),
            kind: TraceKind::Provision { pool: "cpu_cores".into(), units: 100 },
        },
        TraceEvent {
            at: SimTime(5 * ns),
            kind: TraceKind::Submit {
                action: 1,
                traj: 1,
                kind: "env_exec".into(),
                queue_depth: 1,
            },
        },
        TraceEvent {
            at: SimTime(15 * ns),
            kind: TraceKind::Complete { action: 1, outcome: "done".into(), retries: 0 },
        },
        TraceEvent {
            at: SimTime(100 * ns),
            kind: TraceKind::Provision { pool: "cpu_cores".into(), units: 50 },
        },
        TraceEvent {
            at: SimTime(200 * ns),
            kind: TraceKind::TrajEnd { traj: 1, failed: false, restarts: 0 },
        },
    ];
    let stats = trace_pool_stats(&events);
    let cpu = &stats["cpu_cores"];
    assert_eq!(cpu.actions, 1);
    assert!((cpu.mean_act_secs - 10.0).abs() < 1e-9);
    // 100u × 100s + 50u × 100s = 15000 unit-s
    assert!((cpu.unit_hours - 15000.0 / 3600.0).abs() < 1e-9, "{}", cpu.unit_hours);
}

#[test]
fn admission_overlaps_cold_start_at_equal_billing() {
    // The acceptance differential: on coldstart-storm, pre-admitting
    // queued work against billed-but-warming capacity must (1) complete
    // everything, (2) keep the bill byte-equal (admission moves apply
    // instants, never billing points), and (3) never raise mean ACT —
    // queue wait overlaps the cold start instead of following it.
    let mut off_spec = pack_by_name("coldstart-storm").unwrap();
    off_spec.autoscale = Some(AutoscaleCfg::default());
    let mut on_spec = off_spec.clone();
    on_spec.autoscale.as_mut().unwrap().admission = true;
    let off = run_scenario(&off_spec, BackendKind::Tangram).unwrap();
    let on = run_scenario(&on_spec, BackendKind::Tangram).unwrap();

    assert_eq!(on.metrics.trajectories.len(), off.metrics.trajectories.len());
    assert_eq!(on.metrics.failed_actions(), 0);

    // Billing points never move (scale-ups bill from the decision instant
    // either way), but earlier applies change post-apply dynamics, so a
    // later scale-DOWN decision may drift by an evaluation tick or two —
    // savings must agree up to that drift, nothing more.
    let (s_on, s_off) = (on.metrics.savings_vs_static(), off.metrics.savings_vs_static());
    assert!(s_off > 0.0);
    assert!(
        (s_on - s_off).abs() < 0.01,
        "savings moved past decision-timing drift: {s_on} vs {s_off}"
    );

    let (a_on, a_off) = (on.metrics.mean_act(), off.metrics.mean_act());
    assert!(
        a_on <= a_off + 1e-9,
        "admission must not raise mean ACT: {a_on:.4}s !<= {a_off:.4}s"
    );

    // deterministic: the admission path schedules its wakeups from
    // autoscaler state only, so two runs are byte-identical
    let on2 = run_scenario(&on_spec, BackendKind::Tangram).unwrap();
    assert_eq!(
        summary_json(&on.metrics).to_string(),
        summary_json(&on2.metrics).to_string()
    );
    assert_eq!(on.events, on2.events);
}

#[test]
fn admission_trace_records_and_replays() {
    // record → parse → replay byte-identity with admission AND the cost
    // model embedded in the spec (self-contained trace files)
    use arl_tangram::scenario::replay_trace;
    let mut spec = pack_by_name("coldstart-storm").unwrap();
    spec.autoscale = Some(AutoscaleCfg { admission: true, ..AutoscaleCfg::default() });
    spec.cost = Some(CostModel::default());
    let outcome = run_scenario(&spec, BackendKind::Tangram).unwrap();
    let text = trace_file_contents(&spec, BackendKind::Tangram, &outcome);
    let recorded = parse_trace_file(&text).unwrap();
    assert_eq!(recorded.spec.autoscale, spec.autoscale, "admission must survive the file");
    assert_eq!(recorded.spec.cost, spec.cost, "rate card must survive the file");
    let report = replay_trace(&recorded).unwrap();
    assert!(
        report.identical,
        "admission replay diverged: {:?} {:?}",
        report.summary_diff, report.trace_divergences
    );
}

#[test]
fn cost_model_prices_the_autoscaled_run() {
    let mut spec = pack_by_name("coldstart-storm").unwrap();
    spec.autoscale = Some(AutoscaleCfg::default());
    spec.cost = Some(CostModel::default());
    let outcome = run_scenario(&spec, BackendKind::Tangram).unwrap();
    let m = &outcome.metrics;
    assert!(m.cost_rates.is_some(), "spec cost model must reach the metrics");
    let weighted = m.savings_vs_static_cost();
    assert!(weighted.is_finite());
    assert!(weighted > 0.0, "autoscaled run must save dollars too: {weighted}");
    let rows = m.cost_rows();
    assert!(!rows.is_empty());
    for (pool, rate, used, stat) in &rows {
        assert!(rate.is_finite() && *rate > 0.0, "{pool}: rate {rate}");
        assert!(used.is_finite() && stat.is_finite());
        assert!(used <= stat, "{pool}: used$ {used} !<= static$ {stat}");
    }
    // summary carries the dollar keys for cost-model runs…
    let s = summary_json(m).to_string();
    assert!(s.contains("savings_vs_static_cost"));
    assert!(s.contains("pool_cost"));
    // …and cost-free runs keep their pre-cost summary bytes
    let mut plain = pack_by_name("coldstart-storm").unwrap();
    plain.autoscale = Some(AutoscaleCfg::default());
    let plain_out = run_scenario(&plain, BackendKind::Tangram).unwrap();
    assert!(!summary_json(&plain_out.metrics).to_string().contains("pool_cost"));
}

// ---------------------------------------------------------------------------
// billed_units under interleaved Decide/Apply (testkit property)
// ---------------------------------------------------------------------------

/// Per-round observed load for a fixed set of API endpoints of one pool:
/// `rounds[i][ep] = (queued, in_use)`.
#[derive(Debug, Clone)]
struct BilledCase {
    rounds: Vec<Vec<(u64, u64)>>,
}

struct BilledGen {
    endpoints: usize,
}

impl arl_tangram::testkit::Gen for BilledGen {
    type Value = BilledCase;
    fn generate(&self, rng: &mut arl_tangram::util::rng::Rng) -> BilledCase {
        let rounds = rng.range(8, 40) as usize;
        BilledCase {
            rounds: (0..rounds)
                .map(|_| {
                    (0..self.endpoints)
                        .map(|_| (rng.range(0, 4), rng.range(0, 120)))
                        .collect()
                })
                .collect(),
        }
    }
    fn shrink(&self, v: &BilledCase) -> Vec<BilledCase> {
        let mut out = vec![];
        if v.rounds.len() > 1 {
            out.push(BilledCase { rounds: v.rounds[..v.rounds.len() / 2].to_vec() });
            let mut minus_one = v.clone();
            minus_one.rounds.pop();
            out.push(minus_one);
        }
        // quiet the last round (drives toward minimal failing load shapes)
        if let Some(last) = v.rounds.last() {
            if last.iter().any(|&(q, u)| q + u > 0) {
                let mut quiet = v.clone();
                *quiet.rounds.last_mut().unwrap() = vec![(0, 0); last.len()];
                out.push(quiet);
            }
        }
        out
    }
}

#[test]
fn billed_units_survive_interleaved_decides_and_applies() {
    // Property (satellite of the lane refactor): with multiple endpoints
    // of one pool scaling independently, the folded pool bill
    // (`Autoscaler::billed_units`) must (1) keep every warming
    // requisition on the bill — one endpoint's Apply never un-bills
    // another endpoint's pending scale-up — and (2) be monotone
    // non-decreasing across evaluations while anything is warming and no
    // scale-down applied.
    const BASE: u64 = 100;
    const ENDPOINTS: usize = 3;
    let generator = BilledGen { endpoints: ENDPOINTS };
    let cases = arl_tangram::testkit::default_cases().min(128);
    arl_tangram::testkit::check("billed_units_interleaved", &generator, cases, |case| {
        let mut asc = Autoscaler::new(AutoscaleCfg::default());
        let mut applied: Vec<f64> = vec![1.0; ENDPOINTS];
        let mut warming: Vec<Option<f64>> = vec![None; ENDPOINTS];
        let mut prev_billed = asc.billed_units(PoolClass::Api);
        for (i, round) in case.rounds.iter().enumerate() {
            let now = arl_tangram::sim::SimTime(2_000_000_000 * i as u64);
            let obs: Vec<PoolPressure> = round
                .iter()
                .enumerate()
                .map(|(ep, &(queued, in_use))| PoolPressure {
                    key: LaneKey::endpoint(PoolClass::Api, ep as u32),
                    queued,
                    queued_units: queued,
                    in_use_units: in_use,
                    provisioned_units: BASE,
                    baseline_units: BASE,
                })
                .collect();
            let cmds = asc.eval(now, &obs);
            let mut scaled_down = false;
            for cmd in &cmds {
                match cmd {
                    ScaleCmd::Decide { key: LaneKey { endpoint: Some(e), .. }, factor, .. } => {
                        warming[*e as usize] = Some(*factor);
                    }
                    ScaleCmd::Apply { key: LaneKey { endpoint: Some(e), .. }, factor, .. } => {
                        let e = *e as usize;
                        if *factor < applied[e] - 1e-9 {
                            scaled_down = true;
                        }
                        applied[e] = *factor;
                        warming[e] = None;
                    }
                    other => return Err(format!("unexpected endpoint-less cmd {other:?}")),
                }
            }
            let billed = asc.billed_units(PoolClass::Api);
            // (1) the folded bill covers every target at its *effective*
            // factor — a warming requisition counts at its requisitioned
            // factor, so no Apply on a sibling endpoint can un-bill it
            let expected: u64 = (0..ENDPOINTS)
                .map(|e| (BASE as f64 * warming[e].unwrap_or(applied[e])).round() as u64)
                .sum::<u64>()
                .max(1);
            if billed != expected {
                return Err(format!(
                    "round {i}: billed {billed} != model {expected} \
                     (applied {applied:?}, warming {warming:?})"
                ));
            }
            // (2) monotone while warming, absent an applied scale-down
            if warming.iter().any(Option::is_some) && !scaled_down && billed < prev_billed {
                return Err(format!(
                    "round {i}: billed fell {prev_billed} -> {billed} with a warming \
                     requisition and no scale-down"
                ));
            }
            prev_billed = billed;
        }
        Ok(())
    });
}

#[test]
fn inelastic_baselines_ignore_the_autoscaler() {
    // serverless supports the pack but exposes no resizable class: the run
    // must complete with zero scale events and zero savings
    let mut spec = pack_by_name("coldstart-storm").unwrap();
    spec.autoscale = Some(AutoscaleCfg::default());
    let outcome = run_scenario(&spec, BackendKind::Serverless).unwrap();
    let scales = outcome
        .events
        .iter()
        .filter(|e| matches!(e.kind, TraceKind::Scale { .. }))
        .count();
    assert_eq!(scales, 0, "inelastic baseline must never scale");
    assert!(outcome.metrics.savings_vs_static().abs() < 1e-12);
}
