//! Differential verification of dirty-pool incremental scheduling.
//!
//! The coordinator schedules only pools whose state changed since the last
//! pump (`TangramCfg::full_sweep = false`, the default). These tests run
//! every built-in scenario pack under both modes and assert the dirty set
//! (1) completes identical work and (2) does it with strictly fewer
//! elastic-scheduler invocations — the paper's sub-ms decision budget is
//! won by not rescanning `O(pools)` queues per event.
//!
//! Also hosts the queue-stall-under-cordon regression (bugfix satellite):
//! a `cpu_pool_scale` cordon that shrinks a node below the queue head's
//! minimum used to swallow the forced-head allocation error with no wakeup
//! to retry it; the cordon-restore injection now re-dirties every CPU pool.

use arl_tangram::action::TaskId;
use arl_tangram::config::BackendKind;
use arl_tangram::coordinator::{run_session, RunCfg, Session, TangramBackend, TangramCfg};
use arl_tangram::rollout::workloads::{Catalog, CatalogCfg, Workload, WorkloadKind};
use arl_tangram::scenario::{builtin_packs, run_scenario_tangram, ScenarioEvent, TimedEvent};
use arl_tangram::sim::{SimDur, SimTime};

#[test]
fn dirty_pool_matches_full_sweep_at_fewer_invocations() {
    for spec in builtin_packs() {
        if spec.workloads_for(BackendKind::Tangram).is_empty() {
            continue;
        }
        let (dirty, sd) = run_scenario_tangram(&spec, false).unwrap();
        let (sweep, ss) = run_scenario_tangram(&spec, true).unwrap();

        // identical work completed…
        assert_eq!(
            dirty.metrics.trajectories.len(),
            sweep.metrics.trajectories.len(),
            "'{}': trajectory counts diverged",
            spec.name
        );
        assert_eq!(
            dirty.metrics.actions.len(),
            sweep.metrics.actions.len(),
            "'{}': action counts diverged",
            spec.name
        );
        assert_eq!(
            dirty.metrics.failed_actions(),
            sweep.metrics.failed_actions(),
            "'{}': failure counts diverged",
            spec.name
        );
        assert_eq!(
            dirty.metrics.total_retries(),
            sweep.metrics.total_retries(),
            "'{}': retry counts diverged",
            spec.name
        );

        // …at no more scheduler invocations; packs exercising the CPU/GPU
        // elastic pools (coding / mopd mixes) must be *strictly* cheaper.
        assert!(
            sd.invocations <= ss.invocations,
            "'{}': dirty {} > sweep {}",
            spec.name,
            sd.invocations,
            ss.invocations
        );
        let has_elastic_pools = spec
            .workloads
            .iter()
            .chain(spec.tenants.iter().flat_map(|t| t.workloads.iter()))
            .any(|&w| matches!(w, WorkloadKind::Coding | WorkloadKind::Mopd));
        if has_elastic_pools {
            assert!(
                sd.invocations < ss.invocations,
                "'{}': dirty-pool scheduling saved nothing ({} vs {})",
                spec.name,
                sd.invocations,
                ss.invocations
            );
        }
    }
}

#[test]
fn dirty_pool_and_sweep_agree_per_action() {
    // Stronger differential on the fault-free pack: the per-action records
    // (allocation, timing, retries) must match decision-for-decision.
    let spec = builtin_packs().into_iter().find(|s| s.name == "steady-mix").unwrap();
    let (dirty, _) = run_scenario_tangram(&spec, false).unwrap();
    let (sweep, _) = run_scenario_tangram(&spec, true).unwrap();
    assert_eq!(dirty.metrics.actions.len(), sweep.metrics.actions.len());
    for (d, s) in dirty.metrics.actions.iter().zip(sweep.metrics.actions.iter()) {
        assert_eq!(d.id, s.id, "record order diverged");
        assert_eq!(d.units, s.units, "allocation diverged for {:?}", d.id);
        assert_eq!(d.started, s.started, "start time diverged for {:?}", d.id);
        assert_eq!(d.finished, s.finished, "finish time diverged for {:?}", d.id);
        assert_eq!(d.retries, s.retries, "retries diverged for {:?}", d.id);
    }
}

fn at(secs: u64, event: ScenarioEvent) -> TimedEvent {
    TimedEvent { at: SimTime(SimDur::from_secs(secs).0), event }
}

#[test]
fn cordoned_node_recovers_on_restore() {
    // Wide reward actions (fixed 8-core DoP) on a single 16-core node; a
    // 0.1× cordon leaves 2 schedulable cores, so once every trajectory is
    // blocked at its reward the node is idle with a queue it cannot start
    // and NO event of its own will ever fire again. The only remaining
    // event is the cordon restore — which must re-dirty the pool and let
    // every trajectory finish (pre-fix: the allocation error was swallowed
    // and the run ended with the queue still loaded).
    let cat = Catalog::build(&CatalogCfg {
        cpu_nodes: 1,
        cores_per_node: 16,
        gpu_nodes: 1,
        n_teachers: 2,
        ..CatalogCfg::default()
    });
    let mut be = TangramBackend::new(
        &cat,
        TangramCfg {
            cpu_nodes: 1,
            numa_per_node: 2,
            cores_per_numa: 8,
            node_mem_gb: 512,
            gpu_nodes: 1,
            ..TangramCfg::default()
        },
    );
    let mut wl = Workload::new(TaskId(0), WorkloadKind::Coding);
    wl.fixed_dop = Some(8); // every reward needs 8 cores — cordon starves it
    let cfg = RunCfg { batch: 4, steps: 1, seed: 77, ..RunCfg::default() };
    let events = vec![
        at(30, ScenarioEvent::CpuPoolScale { factor: 0.1 }),
        at(2_000, ScenarioEvent::CpuPoolScale { factor: 1.0 }),
    ];
    let mut session = Session::new().with_injections(events);
    let m = run_session(&mut be, &cat, &[wl], &cfg, &mut session);
    assert_eq!(m.trajectories.len(), 4, "trajectories lost under cordon");
    assert_eq!(m.failed_actions(), 0);
    assert_eq!(be.cpu.free_cores(), 16, "cores leaked across the cordon");
}

#[test]
fn deep_pool_squeeze_scenario_completes() {
    // Scenario-level regression: the pool-squeeze pack at a 0.1× cordon
    // (instead of its stock 0.5×) must still finish every trajectory after
    // the restore event.
    let mut spec = builtin_packs().into_iter().find(|s| s.name == "pool-squeeze").unwrap();
    spec.name = "deep-squeeze".into();
    spec.events = vec![
        at(20, ScenarioEvent::CpuPoolScale { factor: 0.1 }),
        at(150, ScenarioEvent::CpuPoolScale { factor: 1.0 }),
    ];
    let (outcome, _) = run_scenario_tangram(&spec, false).unwrap();
    let expected = spec.workloads_for(BackendKind::Tangram).len()
        * spec.batch
        * spec.steps as usize;
    assert_eq!(
        outcome.metrics.trajectories.len(),
        expected,
        "trajectories lost under the deep squeeze"
    );
    assert_eq!(outcome.metrics.failed_actions(), 0);
}
