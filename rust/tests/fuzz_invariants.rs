//! The seeded scenario fuzzer + invariant oracle (tier-1 slice).
//!
//! `scenario --fuzz` hunts scheduler bugs by sampling random-but-
//! deterministic specs and running every accumulated contract over each
//! execution. These tests pin the harness itself: generator determinism
//! and validity, the oracle's clean verdict over a fixed seed slice, the
//! committed regression corpus (`testdata/fuzz_seeds.txt` — every seed a
//! past failure or a sentinel), the failure minimizer, and GPU cordon
//! determinism under *fuzzed* cache residency (previously hand-built
//! fixtures only).

use arl_tangram::action::ServiceId;
use arl_tangram::cluster::{GpuCluster, GpuNodeId};
use arl_tangram::config::BackendKind;
use arl_tangram::scenario::{fuzz_spec, run_scenario_tangram, trace_file_contents, ScenarioSpec};
use arl_tangram::sim::SimTime;
use arl_tangram::testkit::oracle::{check_seed, check_spec, FuzzSpecGen};
use arl_tangram::testkit::shrink_failure;
use arl_tangram::util::rng::{Rng, SplitMix64};

#[test]
fn fuzz_spec_is_deterministic_including_trace() {
    // acceptance: same seed twice -> byte-identical spec AND recorded trace
    for seed in [0u64, 7, 1234, 99_999] {
        let a = fuzz_spec(seed);
        let b = fuzz_spec(seed);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string(), "spec drifted, seed {seed}");
        let (out_a, _) = run_scenario_tangram(&a, false).unwrap();
        let (out_b, _) = run_scenario_tangram(&b, false).unwrap();
        let trace_a = trace_file_contents(&a, BackendKind::Tangram, &out_a);
        let trace_b = trace_file_contents(&b, BackendKind::Tangram, &out_b);
        assert_eq!(trace_a, trace_b, "trace drifted, seed {seed}");
    }
}

#[test]
fn nearby_seeds_diverge() {
    let a = fuzz_spec(1).to_json().to_string();
    let b = fuzz_spec(2).to_json().to_string();
    assert_ne!(a, b, "adjacent seeds must not collide");
}

#[test]
fn fuzz_specs_validate_and_round_trip() {
    for seed in 0..200 {
        let spec = fuzz_spec(seed);
        spec.validate().unwrap_or_else(|e| panic!("seed {seed} invalid: {e}"));
        let text = spec.to_json().to_string();
        let back = ScenarioSpec::from_json(&text).unwrap();
        assert_eq!(back.to_json().to_string(), text, "seed {seed} JSON round-trip drifted");
    }
}

#[test]
fn oracle_clean_over_seed_slice() {
    // a slice of the CI smoke range; the fuzz-smoke CI step covers 50
    for seed in 0..8 {
        let report = check_seed(seed).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(report.is_clean(), "seed {seed}:\n{}", report.describe());
        assert!(report.actions > 0, "seed {seed} completed no actions");
    }
}

#[test]
fn regression_corpus_stays_clean() {
    // every committed seed replays through the FULL oracle; a failing fuzz
    // seed gets minimized, fixed, and appended here permanently
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/testdata/fuzz_seeds.txt");
    let text = std::fs::read_to_string(path).expect("fuzz_seeds.txt missing");
    let mut checked = 0;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let seed: u64 = line.parse().unwrap_or_else(|_| panic!("bad corpus line '{line}'"));
        let report = check_seed(seed).unwrap_or_else(|e| panic!("corpus seed {seed}: {e}"));
        assert!(report.is_clean(), "corpus seed {seed} regressed:\n{}", report.describe());
        checked += 1;
    }
    assert!(checked >= 8, "corpus suspiciously small ({checked} seeds)");
}

#[test]
fn minimizer_shrinks_timeline_simplest_first() {
    // a synthetic "any fault timeline fails" property must shrink a 3-4
    // event spec down to a single event, trying whole-timeline drops first
    let mut seed = 0;
    let spec = loop {
        let s = fuzz_spec(seed);
        if s.events.len() >= 3 {
            break s;
        }
        seed += 1;
    };
    let prop = |s: &ScenarioSpec| {
        if s.events.is_empty() {
            Ok(())
        } else {
            Err(format!("{} events", s.events.len()))
        }
    };
    let original_events = spec.events.len();
    let msg = format!("{original_events} events");
    let (best, _) = shrink_failure(&FuzzSpecGen, spec, msg, &prop, 200);
    assert_eq!(best.events.len(), 1, "expected a single-event reproduction");
    assert!(best.validate().is_ok(), "shrunk spec must stay valid");
    assert!(original_events > 1);
}

#[test]
fn minimizer_strips_autoscale_and_cost() {
    let mut seed = 0;
    let spec = loop {
        let s = fuzz_spec(seed);
        if s.autoscale.is_some() && s.cost.is_some() {
            break s;
        }
        seed += 1;
    };
    // property independent of autoscale/cost: they must both be dropped
    let prop = |s: &ScenarioSpec| {
        if s.batch >= 2 {
            Err("batch too big".to_string())
        } else {
            Ok(())
        }
    };
    let (best, _) = shrink_failure(&FuzzSpecGen, spec, "batch".into(), &prop, 200);
    assert!(best.autoscale.is_none(), "autoscale not stripped");
    assert!(best.cost.is_none(), "cost card not stripped");
    assert!(best.events.is_empty(), "events not stripped");
    assert_eq!(best.batch, 2, "batch not minimized");
}

#[test]
fn fuzzed_multi_tenant_specs_pass_the_oracle() {
    // the tenancy fork draws from its own salted stream, so roughly half
    // the seeds re-home their workloads under 2-3 weighted tenants; those
    // must clear the full battery (including the tenant-conservation and
    // WFQ-neutrality invariants) just like single-tenant specs
    let mut checked = 0;
    for seed in 0..64 {
        let spec = fuzz_spec(seed);
        if spec.tenants.len() < 2 {
            continue;
        }
        let report = check_spec(&spec).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(report.is_clean(), "seed {seed}:\n{}", report.describe());
        checked += 1;
        if checked == 4 {
            break; // full battery per spec — keep the tier-1 slice bounded
        }
    }
    assert!(checked >= 4, "fuzzer produced too few multi-tenant specs ({checked})");
}

#[test]
fn minimizer_flattens_tenancy_first() {
    // a failure independent of tenancy must shrink back to the flat
    // single-tenant shape before any other simplification is attempted
    let mut seed = 0;
    let spec = loop {
        let s = fuzz_spec(seed);
        if s.tenants.len() >= 2 {
            break s;
        }
        seed += 1;
    };
    let prop = |s: &ScenarioSpec| {
        if s.batch >= 2 {
            Err("batch too big".to_string())
        } else {
            Ok(())
        }
    };
    let (best, _) = shrink_failure(&FuzzSpecGen, spec, "batch".into(), &prop, 200);
    assert!(best.tenants.is_empty(), "tenancy not flattened away");
    assert!(!best.workloads.is_empty(), "workloads lost in the flatten");
    assert!(best.validate().is_ok(), "shrunk spec must stay valid");
}

#[test]
fn oracle_flags_a_corrupted_run() {
    // sanity: the battery is not vacuous — a spec the engine cannot even
    // validate must surface as Err, not as a clean report
    let mut spec = fuzz_spec(0);
    spec.batch = 0;
    assert!(check_spec(&spec).is_err());
}

// ---- GPU cordon determinism under fuzzed cache residency ------------------

/// Build an `n`-node cluster with pseudo-random cache residency planted via
/// the public allocate/release path (the only way `last_used` tags enter).
fn fuzzed_cluster(n: u32, seed: u64) -> GpuCluster {
    let mut cluster = GpuCluster::new(n);
    let mut r = Rng::new(seed);
    let mut held = Vec::new();
    for _ in 0..(n as usize * 3) {
        let service = ServiceId(r.range(0, 5) as u32);
        let dop = *r.pick(&[1u8, 2, 4, 8]);
        if let Some(alloc) = cluster.allocate(service, dop) {
            held.push((alloc.chunk, service, dop));
        }
    }
    for (chunk, service, dop) in held {
        let at = SimTime(r.range(1, 1_000_000_000));
        cluster.release(chunk, service, dop, at);
    }
    cluster
}

#[test]
fn cordons_are_coldest_first_with_id_tiebreak() {
    let factors = [0.125f64, 0.25, 0.375, 0.5, 0.625, 0.75, 1.0];
    let mut sm = SplitMix64::new(0xC04D_0135);
    for case in 0..32u64 {
        let n = 3 + (case % 4) as u32; // 3..=6 nodes
        let seed = sm.next_u64();
        let f = *sm.pick(&factors);
        let mut cluster = fuzzed_cluster(n, seed);

        // expected cordon set from the public per-node state BEFORE the
        // resize: idle nodes ranked coldest-first, higher id breaking ties
        let mut rank: Vec<(bool, SimTime, std::cmp::Reverse<u32>)> = (0..n)
            .map(|i| {
                let node = cluster.node(GpuNodeId(i));
                (node.busy_gpus() > 0, node.cache_hotness(), std::cmp::Reverse(i))
            })
            .collect();
        rank.sort();
        let target_online = ((n as f64 * f).round() as u32).clamp(1, n);
        let mut expect_cordoned = Vec::new();
        for key in rank.iter().take((n - target_online) as usize) {
            expect_cordoned.push(key.2 .0);
        }

        let cordoned = cluster.set_pool_scale(f);
        assert_eq!(cordoned, n - target_online, "cordon count, case {case}");
        assert!(n - cluster.cordoned_nodes() >= 1, "no node online, case {case}");
        for id in 0..n {
            let node = cluster.node(GpuNodeId(id));
            let expect = expect_cordoned.contains(&id);
            assert_eq!(
                node.is_cordoned(),
                expect,
                "case {case}: node {id} cordon state (expected set {expect_cordoned:?})"
            );
            if node.is_cordoned() {
                // cordoning flushes residency: a deprovisioned node must
                // not advertise warm caches
                assert_eq!(
                    node.cache_hotness(),
                    SimTime::ZERO,
                    "case {case}: node {id} kept its cache across a cordon"
                );
            }
        }
    }
}

#[test]
fn cordon_selection_is_deterministic() {
    for case in 0..8u64 {
        let n = 4 + (case % 3) as u32;
        let mut a = fuzzed_cluster(n, case * 17 + 1);
        let mut b = fuzzed_cluster(n, case * 17 + 1);
        a.set_pool_scale(0.4);
        b.set_pool_scale(0.4);
        for id in 0..n {
            assert_eq!(
                a.node(GpuNodeId(id)).is_cordoned(),
                b.node(GpuNodeId(id)).is_cordoned(),
                "case {case}: node {id} cordon state diverged"
            );
        }
    }
}

#[test]
fn equal_hotness_cordons_higher_ids_first() {
    // untouched cluster: every node's hotness is ZERO, so the tie-break
    // alone decides — higher node ids are cordoned first
    let mut cluster = GpuCluster::new(4);
    let cordoned = cluster.set_pool_scale(0.5);
    assert_eq!(cordoned, 2);
    assert!(!cluster.node(GpuNodeId(0)).is_cordoned());
    assert!(!cluster.node(GpuNodeId(1)).is_cordoned());
    assert!(cluster.node(GpuNodeId(2)).is_cordoned());
    assert!(cluster.node(GpuNodeId(3)).is_cordoned());
}

#[test]
fn at_least_one_node_survives_any_factor() {
    for &f in &[0.0f64, 0.01, 0.05, 0.1] {
        let mut cluster = fuzzed_cluster(3, 99);
        cluster.set_pool_scale(f);
        assert!(3 - cluster.cordoned_nodes() >= 1, "factor {f} cordoned everything");
    }
}
