//! Golden-trace regression suite: every built-in scenario pack × every
//! supported backend must replay **byte-identical** against the trace files
//! committed under `rust/testdata/golden/`.
//!
//! This is the cross-PR quality ratchet for scheduler changes: the
//! conformance suite catches nondeterminism *within* one build, the golden
//! files catch behavioural drift *between* builds. Workflow:
//!
//! * Missing golden files are recorded ("blessed") by this test and the
//!   test passes — commit the generated files to pin current behaviour.
//! * When a scheduling change is **intentional**, regenerate with
//!   `ARL_GOLDEN_BLESS=1 cargo test --test golden_traces` and commit the
//!   diff (reviewers see exactly which decisions moved). See ROADMAP.md
//!   "Golden traces".

use arl_tangram::autoscale::AutoscaleCfg;
use arl_tangram::config::BackendKind;
use arl_tangram::lanes::CostModel;
use arl_tangram::scenario::{builtin_packs, run_scenario, trace_file_contents, ScenarioSpec};
use std::path::PathBuf;

fn golden_dir() -> PathBuf {
    // ARL_GOLDEN_DIR redirects the suite to another tree — the CI staleness
    // guard blesses into a temp dir and `diff -r`s it against the committed
    // rust/testdata/golden/, so an uncommitted behaviour change fails even
    // when a pack has no golden file yet.
    if let Ok(dir) = std::env::var("ARL_GOLDEN_DIR") {
        if !dir.is_empty() {
            return PathBuf::from(dir);
        }
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("testdata").join("golden")
}

/// Both tests touch the golden directory; serialize them (tests in one
/// binary run concurrently) so the parser never sees a half-written bless.
static GOLDEN_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Check (or bless) one pack×backend variant against its golden file.
/// Returns `true` when the file was freshly blessed.
fn check_variant(
    dir: &std::path::Path,
    spec: &ScenarioSpec,
    backend: BackendKind,
    suffix: &str,
    bless_all: bool,
    blessed: &mut Vec<String>,
) -> bool {
    let path = dir.join(format!("{}__{}{suffix}.jsonl", spec.name, backend.name()));
    let outcome = run_scenario(spec, backend).expect("scenario runs");
    let fresh = trace_file_contents(spec, backend, &outcome);
    if bless_all || !path.exists() {
        std::fs::write(&path, &fresh).expect("write golden trace");
        blessed.push(path.display().to_string());
        return true;
    }
    let recorded = std::fs::read_to_string(&path).expect("read golden trace");
    if recorded != fresh {
        let diverged = recorded
            .lines()
            .zip(fresh.lines())
            .enumerate()
            .find(|(_, (a, b))| a != b)
            .map(|(i, (a, b))| format!("line {}:\n  golden: {a}\n  fresh:  {b}", i + 1))
            .unwrap_or_else(|| {
                format!(
                    "line counts differ: golden {} vs fresh {}",
                    recorded.lines().count(),
                    fresh.lines().count()
                )
            });
        panic!(
            "golden trace diverged: {}\n{diverged}\n\
             If this scheduling change is INTENTIONAL, regenerate with\n  \
             ARL_GOLDEN_BLESS=1 cargo test --test golden_traces\n\
             and commit the updated rust/testdata/golden/ files (ROADMAP.md \"Golden traces\").",
            path.display(),
        );
    }
    false
}

#[test]
fn every_pack_and_backend_replays_byte_identical_against_golden() {
    let _guard = GOLDEN_LOCK.lock().unwrap();
    let bless_all = std::env::var("ARL_GOLDEN_BLESS").map_or(false, |v| v == "1");
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).expect("create golden dir");
    let mut blessed: Vec<String> = Vec::new();
    let mut checked = 0usize;
    for spec in builtin_packs() {
        for backend in BackendKind::ALL {
            if spec.workloads_for(backend).is_empty() {
                continue; // single-purpose baseline: unsupported mix subset
            }
            if !check_variant(&dir, &spec, backend, "", bless_all, &mut blessed) {
                checked += 1;
            }
        }
        // autoscaled variant: tangram is the only elastic backend, so one
        // autoscaled golden per pack pins the full scale-decision stream
        // (the autoscale config is embedded in the trace header's spec).
        // The default rate card rides along, pinning the cost header +
        // summary additions; cost is pure reporting, so the event stream
        // is identical to a cost-free autoscaled run.
        let mut auto_spec = spec.clone();
        auto_spec.autoscale = Some(AutoscaleCfg::default());
        auto_spec.cost = Some(CostModel::default());
        if !check_variant(
            &dir,
            &auto_spec,
            BackendKind::Tangram,
            "__autoscaled",
            bless_all,
            &mut blessed,
        ) {
            checked += 1;
        }
    }
    if !blessed.is_empty() {
        eprintln!(
            "blessed {} golden trace(s) — commit rust/testdata/golden/ to pin them:\n  {}",
            blessed.len(),
            blessed.join("\n  ")
        );
    }
    // acceptance floor: 11 packs × their backends (40 combos, the tenant
    // packs cover 6) plus one autoscaled tangram trace per pack (11)
    assert!(
        checked + blessed.len() >= 51,
        "pack×backend golden coverage shrank: {} combos",
        checked + blessed.len()
    );
}

#[test]
fn blessed_golden_files_parse_as_trace_files() {
    // Whatever is committed (or just blessed) must round-trip through the
    // trace-file parser — guards against hand-edited golden files.
    use arl_tangram::scenario::parse_trace_file;
    let _guard = GOLDEN_LOCK.lock().unwrap();
    let dir = golden_dir();
    let Ok(entries) = std::fs::read_dir(&dir) else {
        return; // nothing blessed yet
    };
    for entry in entries {
        let path = entry.expect("dir entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("jsonl") {
            continue;
        }
        let text = std::fs::read_to_string(&path).expect("read golden");
        let parsed = parse_trace_file(&text)
            .unwrap_or_else(|e| panic!("{} is not a valid trace file: {e}", path.display()));
        assert!(!parsed.events.is_empty(), "{} has no events", path.display());
    }
}
