//! Determinism-lint rule fixtures: for each of the seven rules, a source
//! fragment that must FIRE, one that must PASS, and one where an
//! `arl-lint: allow` suppresses the finding. Each firing fixture fails if
//! its rule were disabled, so the battery pins the rule set itself. The
//! final test self-lints `src/` against the committed `lint_baseline.json`
//! — the same check CI runs via `arl-tangram lint`.

use arl_tangram::analysis::{lint_source, lint_tree, Baseline, LintConfig, RuleId};
use std::path::Path;

/// Lint a fragment as if it lived in a decision-path module.
fn lint_decision(src: &str) -> Vec<RuleId> {
    lint_source("src/lanes/fixture.rs", src, &LintConfig::default())
        .into_iter()
        .map(|f| f.rule)
        .collect()
}

/// Lint a fragment as if it lived outside the decision paths.
fn lint_plain(src: &str) -> Vec<RuleId> {
    lint_source("src/metrics/fixture.rs", src, &LintConfig::default())
        .into_iter()
        .map(|f| f.rule)
        .collect()
}

fn fires(rules: &[RuleId], rule: RuleId) -> bool {
    rules.contains(&rule)
}

// ---------------------------------------------------------------------------
// nondet-iteration
// ---------------------------------------------------------------------------

#[test]
fn nondet_iteration_fires_on_hash_iteration_in_decision_path() {
    let src = "
        fn pump(m: &HashMap<u32, u64>) -> u64 {
            let mut acc = 0;
            for (k, v) in m.iter() {
                acc += k as u64 + v;
            }
            acc
        }
    ";
    assert!(fires(&lint_decision(src), RuleId::NondetIteration));
}

#[test]
fn nondet_iteration_fires_on_shared_hash_field() {
    // `queues` is a configured shared hash field — flagged even without a
    // local declaration in this file.
    let src = "
        fn pump(&mut self) {
            for q in self.lane.queues.values_mut() {
                q.touch();
            }
        }
    ";
    assert!(fires(&lint_decision(src), RuleId::NondetIteration));
}

#[test]
fn nondet_iteration_passes_on_btreemap_and_outside_decision_paths() {
    // BTreeMap iteration is deterministic — never flagged.
    let src = "
        fn pump(m: &BTreeMap<u32, u64>) -> u64 {
            m.values().sum()
        }
    ";
    assert!(!fires(&lint_decision(src), RuleId::NondetIteration));
    // HashMap iteration outside a decision path is out of scope.
    let src = "
        fn tally(m: &HashMap<u32, u64>) -> u64 {
            m.values().sum()
        }
    ";
    assert!(!fires(&lint_plain(src), RuleId::NondetIteration));
}

#[test]
fn nondet_iteration_is_scoped_per_function() {
    // `dp` is a HashMap in one fn and a Vec in another: only the HashMap
    // fn's iteration fires.
    let src = "
        fn sparse() {
            let mut dp: HashMap<usize, f64> = HashMap::new();
            for (k, v) in dp.iter() { let _ = (k, v); }
        }
        fn dense() {
            let mut dp = vec![0.0; 8];
            for v in dp.iter() { let _ = v; }
        }
    ";
    let findings = lint_source("src/lanes/fixture.rs", src, &LintConfig::default());
    let hits: Vec<_> =
        findings.iter().filter(|f| f.rule == RuleId::NondetIteration).collect();
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0].line, 4);
}

#[test]
fn nondet_iteration_allow_suppresses() {
    let src = "
        fn pump(m: &HashMap<u32, u64>) -> u64 {
            // arl-lint: allow(nondet-iteration): commutative sum
            m.values().sum()
        }
    ";
    assert!(!fires(&lint_decision(src), RuleId::NondetIteration));
}

#[test]
fn allow_without_reason_grants_nothing() {
    let src = "
        fn pump(m: &HashMap<u32, u64>) -> u64 {
            // arl-lint: allow(nondet-iteration):
            m.values().sum()
        }
    ";
    assert!(fires(&lint_decision(src), RuleId::NondetIteration));
}

// ---------------------------------------------------------------------------
// wall-clock
// ---------------------------------------------------------------------------

#[test]
fn wall_clock_fires_everywhere_but_the_allowlist() {
    let src = "
        fn slow() {
            let t0 = std::time::Instant::now();
            work();
            report(t0.elapsed());
        }
    ";
    assert!(fires(&lint_plain(src), RuleId::WallClock));
    assert!(fires(&lint_decision(src), RuleId::WallClock));
    // the one allowlisted file may hold the Instant
    let allowed = lint_source("src/util/stopwatch.rs", src, &LintConfig::default());
    assert!(!allowed.iter().any(|f| f.rule == RuleId::WallClock));
}

#[test]
fn wall_clock_fires_on_system_time_import() {
    let src = "use std::time::SystemTime;";
    assert!(fires(&lint_plain(src), RuleId::WallClock));
}

#[test]
fn wall_clock_passes_on_sim_time_and_comments() {
    let src = "
        // Instant::now() would be wrong here; SimTime is virtual.
        fn decide(now: SimTime) -> SimTime {
            now + SimDur::from_secs(1)
        }
    ";
    assert!(!fires(&lint_plain(src), RuleId::WallClock));
}

#[test]
fn wall_clock_allow_suppresses() {
    let src = "
        fn slow() {
            // arl-lint: allow(wall-clock): latency probe, never serialized
            let t0 = std::time::Instant::now();
            report(t0.elapsed());
        }
    ";
    assert!(!fires(&lint_plain(src), RuleId::WallClock));
}

// ---------------------------------------------------------------------------
// ambient-rng
// ---------------------------------------------------------------------------

#[test]
fn ambient_rng_fires_on_entropy_taps() {
    assert!(fires(&lint_plain("fn f() { let mut r = thread_rng(); }"), RuleId::AmbientRng));
    assert!(fires(&lint_plain("fn f() { let r = StdRng::from_entropy(); }"), RuleId::AmbientRng));
    assert!(fires(&lint_plain("fn f() { let x = rand::random::<u64>(); }"), RuleId::AmbientRng));
}

#[test]
fn ambient_rng_passes_on_seeded_splitmix() {
    let src = "
        fn f(seed: u64) -> u64 {
            let mut rng = SplitMix64::new(seed);
            rng.next_u64()
        }
    ";
    assert!(!fires(&lint_plain(src), RuleId::AmbientRng));
}

#[test]
fn ambient_rng_allow_suppresses() {
    let src = "
        fn f() {
            // arl-lint: allow(ambient-rng): port-collision jitter, not a decision
            let r = OsRng.next_u64();
        }
    ";
    assert!(!fires(&lint_plain(src), RuleId::AmbientRng));
}

// ---------------------------------------------------------------------------
// raw-factor
// ---------------------------------------------------------------------------

#[test]
fn raw_factor_fires_on_unquantized_arithmetic() {
    let src = "
        fn resize(&mut self, factor: f64) {
            self.units = (self.units as f64 * factor) as u64;
        }
    ";
    assert!(fires(&lint_decision(src), RuleId::RawFactor));
}

#[test]
fn raw_factor_passes_through_quantize() {
    let src = "
        fn resize(&mut self, factor: f64) {
            let factor = self.auto.quantize(factor * self.fault);
            self.apply(factor);
        }
    ";
    assert!(!fires(&lint_decision(src), RuleId::RawFactor));
}

#[test]
fn raw_factor_ignores_non_decision_paths() {
    let src = "
        fn plot(factor: f64) -> f64 {
            factor * 100.0
        }
    ";
    assert!(!fires(&lint_plain(src), RuleId::RawFactor));
}

#[test]
fn raw_factor_allow_suppresses() {
    let src = "
        fn bill(&self, factor: f64) -> f64 {
            // arl-lint: allow(raw-factor): billing display only, no decision
            factor * self.rate
        }
    ";
    assert!(!fires(&lint_decision(src), RuleId::RawFactor));
}

// ---------------------------------------------------------------------------
// panic-budget
// ---------------------------------------------------------------------------

#[test]
fn panic_budget_counts_unwrap_and_expect() {
    let src = "
        fn f(x: Option<u32>, y: Option<u32>) -> u32 {
            let a = x.unwrap();
            a + y.expect(\"known present\")
        }
    ";
    let findings = lint_source("src/metrics/fixture.rs", src, &LintConfig::default());
    assert_eq!(findings.iter().filter(|f| f.rule == RuleId::PanicBudget).count(), 2);
}

#[test]
fn panic_budget_ignores_tests_and_non_calls() {
    let src = "
        #[cfg(test)]
        mod tests {
            #[test]
            fn t() { assert_eq!(parse(\"1\").unwrap(), 1); }
        }
        fn unwrap_like() -> u32 { 1 } // ident named unwrap is not a call
    ";
    assert!(!fires(&lint_plain(src), RuleId::PanicBudget));
}

#[test]
fn panic_budget_allow_suppresses() {
    let src = "
        fn f(x: Option<u32>) -> u32 {
            // arl-lint: allow(panic-budget): invariant: caller checked is_some
            x.unwrap()
        }
    ";
    assert!(!fires(&lint_plain(src), RuleId::PanicBudget));
}

// ---------------------------------------------------------------------------
// golden-surface
// ---------------------------------------------------------------------------

#[test]
fn golden_surface_fires_on_ledger_in_serializers() {
    let src = "
        impl Metrics {
            pub fn to_json(&self) -> Json {
                Json::num(self.ledger.len() as f64)
            }
        }
    ";
    assert!(fires(&lint_plain(src), RuleId::GoldenSurface));
    let src = "
        pub fn summary_json(m: &Metrics) -> Json {
            serialize(&m.ledger)
        }
    ";
    assert!(fires(&lint_plain(src), RuleId::GoldenSurface));
}

#[test]
fn golden_surface_passes_outside_serializers() {
    let src = "
        pub fn audit(&self) -> usize {
            self.ledger.len()
        }
    ";
    assert!(!fires(&lint_plain(src), RuleId::GoldenSurface));
}

#[test]
fn golden_surface_allow_suppresses() {
    let src = "
        pub fn to_json(&self) -> Json {
            // arl-lint: allow(golden-surface): debug dump, not a golden file
            Json::num(self.ledger.len() as f64)
        }
    ";
    assert!(!fires(&lint_plain(src), RuleId::GoldenSurface));
}

// ---------------------------------------------------------------------------
// ambient-threads
// ---------------------------------------------------------------------------

#[test]
fn ambient_threads_fires_on_spawns_and_channels() {
    let src = "
        fn fan_out() {
            let h = std::thread::spawn(|| work());
            h.join().unwrap();
        }
    ";
    assert!(fires(&lint_decision(src), RuleId::AmbientThreads));
    assert!(fires(&lint_plain(src), RuleId::AmbientThreads));
    let src = "
        fn pipe() {
            let (tx, rx) = mpsc::channel();
            tx.send(1).unwrap();
            let _ = rx.recv();
        }
    ";
    assert!(fires(&lint_plain(src), RuleId::AmbientThreads));
    let src = "use std::thread;";
    assert!(fires(&lint_plain(src), RuleId::AmbientThreads));
}

#[test]
fn ambient_threads_passes_on_plain_idents_and_the_worker_pool() {
    // `threads` / a bare `thread` ident without a `::` path are config
    // knobs, not spawns.
    let src = "
        fn plan(threads: usize) -> usize {
            let per_thread = 4;
            threads * per_thread
        }
    ";
    assert!(!fires(&lint_plain(src), RuleId::AmbientThreads));
    // The coordinator's worker pool is the one allowlisted spawn site.
    let src = "
        fn drain() {
            std::thread::scope(|s| { let _ = s; });
        }
    ";
    let allowed = lint_source("src/coordinator/parallel.rs", src, &LintConfig::default());
    assert!(!allowed.iter().any(|f| f.rule == RuleId::AmbientThreads));
    // The same fragment anywhere else fires.
    assert!(fires(&lint_decision(src), RuleId::AmbientThreads));
}

#[test]
fn ambient_threads_allow_suppresses() {
    let src = "
        fn probe() {
            // arl-lint: allow(ambient-threads): watchdog timer, never touches sim state
            let h = std::thread::spawn(|| beat());
            h.join().unwrap();
        }
    ";
    assert!(!fires(&lint_plain(src), RuleId::AmbientThreads));
}

// ---------------------------------------------------------------------------
// self-lint: the tree must match the committed baseline
// ---------------------------------------------------------------------------

#[test]
fn tree_matches_committed_baseline() {
    let findings = lint_tree(Path::new("src"), &LintConfig::default()).expect("lint src/");
    let baseline = Baseline::load(Path::new("lint_baseline.json")).expect("load baseline");
    let cmp = baseline.compare(&findings);
    assert!(
        cmp.ok(),
        "lint drift against lint_baseline.json\nviolations: {:#?}\nstale: {:#?}",
        cmp.violations,
        cmp.stale
    );
}

#[test]
fn tree_has_no_findings_outside_the_panic_budget() {
    // The other five rules are clean by construction (annotations carry
    // the justified exceptions); only the unwrap/expect ratchet has
    // accepted findings.
    let findings = lint_tree(Path::new("src"), &LintConfig::default()).expect("lint src/");
    let hard: Vec<_> =
        findings.iter().filter(|f| f.rule != RuleId::PanicBudget).collect();
    assert!(hard.is_empty(), "non-ratchet findings: {hard:#?}");
}
