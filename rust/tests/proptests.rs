//! Property-based tests over the coordinator's core invariants, using the
//! in-tree `testkit` harness (offline substitute for proptest).
//!
//! Invariants covered:
//!  * DPArrange optimality vs brute force on random instances (both
//!    operators) and feasibility of returned allocations;
//!  * chunk-allocator conservation + legality under random alloc/release;
//!  * scheduler decisions never overshoot availability, respect per-action
//!    unit sets, and preserve FCFS admission;
//!  * Basic manager never exceeds provider limits under random workloads;
//!  * DES engine monotonicity under random event storms;
//!  * routing/batching state conservation in the CPU manager;
//!  * `lanes::CostModel`: cost rows agree with per-pool dollar totals,
//!    endpoint-override resolution is order-independent, and a uniform
//!    rate card reproduces the unweighted savings metric.

use arl_tangram::action::{
    Action, ActionId, ActionKind, ActionSpec, CostSpec, DimCost, ElasticityModel,
    ResourceClass, ResourceRegistry, ServiceId, TaskId, TenantId, TrajId,
};
use arl_tangram::autoscale::{LaneKey, PoolClass, PoolPressure};
use arl_tangram::cluster::cpu::CpuLatency;
use arl_tangram::cluster::gpu::GpuCluster;
use arl_tangram::lanes::CostModel;
use arl_tangram::managers::{BasicManager, CpuManager};
use arl_tangram::metrics::{Metrics, ProvisionRecord};
use arl_tangram::scheduler::{
    dp_arrange, BasicOperator, ChunkOperator, CompletionHeap, DpOperator, ElasticScheduler,
    ResourceMap, ResourceState, SchedulerConfig,
};
use arl_tangram::sim::{Engine, SimDur, SimTime};
use arl_tangram::testkit::{check, default_cases, Gen};
use arl_tangram::util::rng::Rng;
use std::collections::BTreeMap;

// ---------------------------------------------------------------------------
// DPArrange vs brute force
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct DpInstance {
    units: u64,
    sets: Vec<Vec<u64>>,
    durs: Vec<u64>,
    serial: f64,
}

struct DpGen;

impl Gen for DpGen {
    type Value = DpInstance;
    fn generate(&self, rng: &mut Rng) -> DpInstance {
        let units = rng.range(1, 12);
        let m = rng.range(1, 4) as usize;
        let sets: Vec<Vec<u64>> = (0..m)
            .map(|_| {
                let lo = rng.range(1, 3);
                let hi = lo + rng.range(0, 4);
                match rng.range(0, 2) {
                    0 => (lo..=hi).collect(),
                    1 => vec![lo],
                    _ => {
                        let mut v: Vec<u64> =
                            (0..rng.range(1, 3)).map(|_| rng.range(1, 8)).collect();
                        v.sort();
                        v.dedup();
                        v
                    }
                }
            })
            .collect();
        let durs = (0..m).map(|_| rng.range(1, 60)).collect();
        DpInstance { units, sets, durs, serial: rng.f64() * 0.3 }
    }
    fn shrink(&self, v: &DpInstance) -> Vec<DpInstance> {
        let mut out = vec![];
        if v.sets.len() > 1 {
            let mut w = v.clone();
            w.sets.pop();
            w.durs.pop();
            out.push(w);
        }
        if v.units > 1 {
            let mut w = v.clone();
            w.units -= 1;
            out.push(w);
        }
        out
    }
}

fn brute_force_best(
    op: &dyn DpOperator,
    sets: &[Vec<u64>],
    dur: impl Fn(usize, u64) -> SimDur + Copy,
) -> Option<f64> {
    fn rec(
        op: &dyn DpOperator,
        sets: &[Vec<u64>],
        dur: impl Fn(usize, u64) -> SimDur + Copy,
        i: usize,
        state: usize,
        acc: f64,
        best: &mut Option<f64>,
    ) {
        if i == sets.len() {
            if best.map_or(true, |b| acc < b) {
                *best = Some(acc);
            }
            return;
        }
        for &k in &sets[i] {
            if let Some(s2) = op.consume(state, k) {
                rec(op, sets, dur, i + 1, s2, acc + dur(i, k).secs_f64(), best);
            }
        }
    }
    let mut best = None;
    rec(op, sets, dur, 0, op.full_state(), 0.0, &mut best);
    best
}

#[test]
fn prop_dp_arrange_matches_brute_force_basic() {
    check("dp=bruteforce basic", &DpGen, default_cases(), |inst| {
        let op = BasicOperator::new(inst.units);
        let durs = &inst.durs;
        let serial = inst.serial;
        let dur = move |i: usize, k: u64| {
            ElasticityModel::Amdahl { serial_frac: serial }
                .scaled_dur(SimDur::from_secs(durs[i]), k)
        };
        let got = dp_arrange(&op, &inst.sets, dur);
        let want = brute_force_best(&op, &inst.sets, dur);
        match (got, want) {
            (Some(g), Some(w)) => {
                if (g.total_dur_secs - w).abs() > 1e-9 {
                    return Err(format!("dp {} vs bf {w}", g.total_dur_secs));
                }
                let mut state = op.full_state();
                for (i, &k) in g.units.iter().enumerate() {
                    if !inst.sets[i].contains(&k) {
                        return Err(format!("unit {k} not in set {:?}", inst.sets[i]));
                    }
                    state = op.consume(state, k).ok_or("infeasible backtrack")?;
                }
                Ok(())
            }
            (None, None) => Ok(()),
            (g, w) => Err(format!("feasibility mismatch {g:?} vs {w:?}")),
        }
    });
}

#[test]
fn prop_dp_arrange_matches_brute_force_chunks() {
    check("dp=bruteforce chunks", &DpGen, default_cases() / 2, |inst| {
        let total = 16u32;
        let bounds = ChunkOperator::cluster_bounds(total);
        let avail = [
            (inst.units % 3) as u32,
            (inst.units % 2) as u32,
            (inst.durs.first().copied().unwrap_or(0) % 2) as u32,
            1,
        ];
        let op = ChunkOperator::new(avail, bounds);
        let sets: Vec<Vec<u64>> = inst
            .sets
            .iter()
            .map(|s| {
                let mut v: Vec<u64> = s.iter().map(|&k| k.min(8)).collect();
                v.sort();
                v.dedup();
                v
            })
            .collect();
        let durs = &inst.durs;
        let dur = move |i: usize, k: u64| {
            ElasticityModel::PerfectScaling.scaled_dur(SimDur::from_secs(durs[i]), k)
        };
        let got = dp_arrange(&op, &sets, dur);
        let want = brute_force_best(&op, &sets, dur);
        match (got, want) {
            (Some(g), Some(w)) if (g.total_dur_secs - w).abs() < 1e-9 => {
                // the returned allocation must itself be topology-feasible
                // and drawn from each task's unit set
                let mut state = op.full_state();
                for (i, &k) in g.units.iter().enumerate() {
                    if !sets[i].contains(&k) {
                        return Err(format!("unit {k} not in set {:?}", sets[i]));
                    }
                    state = op
                        .consume(state, k)
                        .ok_or(format!("infeasible chunk backtrack at task {i}"))?;
                }
                Ok(())
            }
            (None, None) => Ok(()),
            (g, w) => Err(format!("mismatch {g:?} vs {w:?}")),
        }
    });
}

// ---------------------------------------------------------------------------
// CompletionHeap vs a naive Vec-scan reference model
// ---------------------------------------------------------------------------

/// Op stream for the model test: push, pop, peek, and "update" (pop the
/// earliest entry and re-push it with a shifted completion time — the
/// pattern `estimate`'s drain loop performs).
#[derive(Debug, Clone)]
enum HeapOp {
    Push(u64, u64),
    Pop,
    Peek,
    Update(u64),
}

struct HeapOpsGen;

impl Gen for HeapOpsGen {
    type Value = Vec<HeapOp>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (0..rng.range(1, 60))
            .map(|_| match rng.range(0, 3) {
                0 => HeapOp::Push(rng.range(0, 50), rng.range(0, 6)),
                1 => HeapOp::Pop,
                2 => HeapOp::Peek,
                _ => HeapOp::Update(rng.range(1, 20)),
            })
            .collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = vec![];
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            let mut w = v.clone();
            w.pop();
            out.push(w);
        }
        out
    }
}

/// Naive reference: an unsorted Vec scanned for the minimum (time, units)
/// entry — the spec the heap must agree with on every observable.
#[derive(Default)]
struct VecHeap {
    entries: Vec<(SimTime, u64)>,
    total: u64,
}

impl VecHeap {
    fn push(&mut self, t: SimTime, u: u64) {
        if u == 0 {
            return;
        }
        self.total += u;
        self.entries.push((t, u));
    }
    fn pop(&mut self) -> Option<(SimTime, u64)> {
        let i = self
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, &(t, u))| (t, u))
            .map(|(i, _)| i)?;
        let e = self.entries.swap_remove(i);
        self.total -= e.1;
        Some(e)
    }
    fn peek(&self) -> Option<SimTime> {
        self.entries.iter().map(|&(t, _)| t).min()
    }
}

#[test]
fn prop_completion_heap_matches_vec_reference() {
    check("heap=vec model", &HeapOpsGen, default_cases(), |ops| {
        let mut heap = CompletionHeap::new();
        let mut reference = VecHeap::default();
        for (step, op) in ops.iter().enumerate() {
            match *op {
                HeapOp::Push(t, u) => {
                    heap.push(SimTime(t), u);
                    reference.push(SimTime(t), u);
                }
                HeapOp::Pop => {
                    let got = heap.pop();
                    // the heap must pop the earliest time; among equal
                    // times the entry is interchangeable, so compare
                    // against the reference's (time, units) minimum time
                    // and remove the exact pair the heap returned
                    match got {
                        None => {
                            if reference.peek().is_some() {
                                return Err(format!("step {step}: heap empty, ref not"));
                            }
                        }
                        Some((t, u)) => {
                            let min_t = reference
                                .peek()
                                .ok_or(format!("step {step}: ref empty, heap not"))?;
                            if t != min_t {
                                return Err(format!(
                                    "step {step}: popped {t:?}, earliest is {min_t:?}"
                                ));
                            }
                            let i = reference
                                .entries
                                .iter()
                                .position(|&e| e == (t, u))
                                .ok_or(format!(
                                    "step {step}: heap popped {t:?}/{u} not in reference"
                                ))?;
                            reference.entries.swap_remove(i);
                            reference.total -= u;
                        }
                    }
                }
                HeapOp::Peek => {
                    if heap.peek() != reference.peek() {
                        return Err(format!(
                            "step {step}: peek {:?} vs ref {:?}",
                            heap.peek(),
                            reference.peek()
                        ));
                    }
                }
                HeapOp::Update(delta) => {
                    if let Some((t, u)) = heap.pop() {
                        let min_t =
                            reference.peek().ok_or(format!("step {step}: ref empty on update"))?;
                        if t != min_t {
                            return Err(format!("step {step}: update popped non-min"));
                        }
                        let i = reference
                            .entries
                            .iter()
                            .position(|&e| e == (t, u))
                            .ok_or(format!("step {step}: update pair missing in ref"))?;
                        reference.entries.swap_remove(i);
                        reference.total -= u;
                        let t2 = SimTime(t.0 + delta);
                        heap.push(t2, u);
                        reference.push(t2, u);
                    }
                }
            }
            if heap.total_units() != reference.total {
                return Err(format!(
                    "step {step}: total_units {} vs ref {}",
                    heap.total_units(),
                    reference.total
                ));
            }
            if heap.len() != reference.entries.len() {
                return Err(format!(
                    "step {step}: len {} vs ref {}",
                    heap.len(),
                    reference.entries.len()
                ));
            }
        }
        // drain: both must empty in identical (time, units) order up to
        // equal-time permutations; compare sorted multisets
        let mut a = vec![];
        while let Some(e) = heap.pop() {
            a.push(e);
        }
        let mut b = std::mem::take(&mut reference.entries);
        let mut a_sorted = a.clone();
        a_sorted.sort();
        b.sort();
        if a_sorted != b {
            return Err(format!("drain multiset mismatch {a_sorted:?} vs {b:?}"));
        }
        // drained sequence must be non-decreasing in time
        for w in a.windows(2) {
            if w[1].0 < w[0].0 {
                return Err(format!("drain not time-ordered: {w:?}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// chunk allocator invariants
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct ChunkOps(Vec<(u8, u8)>);

struct ChunkOpsGen;

impl Gen for ChunkOpsGen {
    type Value = ChunkOps;
    fn generate(&self, rng: &mut Rng) -> ChunkOps {
        let n = rng.range(1, 24) as usize;
        ChunkOps(
            (0..n)
                .map(|_| (rng.range(0, 5) as u8, *rng.pick(&[1u8, 2, 4, 8])))
                .collect(),
        )
    }
    fn shrink(&self, v: &ChunkOps) -> Vec<ChunkOps> {
        let mut out = vec![];
        if v.0.len() > 1 {
            out.push(ChunkOps(v.0[..v.0.len() / 2].to_vec()));
            let mut w = v.0.clone();
            w.pop();
            out.push(ChunkOps(w));
        }
        out
    }
}

#[test]
fn prop_chunk_allocator_conserves_gpus() {
    check("chunk conservation", &ChunkOpsGen, default_cases(), |ops| {
        let mut cluster = GpuCluster::new(2);
        let total = cluster.total_gpus();
        let mut held: Vec<(arl_tangram::cluster::gpu::ChunkRef, u8, u8)> = vec![];
        for (i, &(svc, dop)) in ops.0.iter().enumerate() {
            if i % 3 == 2 && !held.is_empty() {
                let (c, s, d) = held.remove(0);
                cluster.release(c, ServiceId(s as u32), d, SimTime(i as u64));
            }
            if let Some(a) = cluster.allocate(ServiceId(svc as u32), dop) {
                if !a.chunk.is_legal() {
                    return Err(format!("illegal chunk {:?}", a.chunk));
                }
                held.push((a.chunk, svc, dop));
            }
            let held_gpus: u32 = held.iter().map(|(c, _, _)| c.size() as u32).sum();
            if cluster.free_gpus() + held_gpus != total {
                return Err(format!(
                    "leak: free {} + held {held_gpus} != {total}",
                    cluster.free_gpus()
                ));
            }
        }
        for (c, s, d) in held {
            cluster.release(c, ServiceId(s as u32), d, SimTime(999));
        }
        if cluster.free_gpus() != total {
            return Err("drain leak".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// scheduler invariants
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct SchedInstance {
    units: u64,
    actions: Vec<(u64, u64, u64, bool)>,
}

struct SchedGen;

impl Gen for SchedGen {
    type Value = SchedInstance;
    fn generate(&self, rng: &mut Rng) -> SchedInstance {
        let units = rng.range(4, 64);
        let n = rng.range(1, 20) as usize;
        let actions = (0..n)
            .map(|_| {
                let min = rng.range(1, 4);
                let max = min + rng.range(0, 12);
                (min, max, rng.range(1, 120), rng.chance(0.6))
            })
            .collect();
        SchedInstance { units, actions }
    }
    fn shrink(&self, v: &SchedInstance) -> Vec<SchedInstance> {
        let mut out = vec![];
        if v.actions.len() > 1 {
            let mut w = v.clone();
            w.actions.truncate(v.actions.len() / 2);
            out.push(w);
        }
        out
    }
}

struct FlatPool(u64);

impl ResourceState for FlatPool {
    fn available_units(&self) -> u64 {
        self.0
    }
    fn accommodate(&self, mins: &[u64]) -> bool {
        mins.iter().sum::<u64>() <= self.0
    }
    fn dp_operator(&self, reserved: &[u64]) -> Box<dyn DpOperator> {
        let used: u64 = reserved.iter().sum();
        Box::new(BasicOperator::new(self.0.saturating_sub(used)))
    }
    fn running_completions(&self) -> Vec<(SimTime, u64)> {
        vec![]
    }
}

#[test]
fn prop_scheduler_never_overallocates() {
    check("sched within capacity", &SchedGen, default_cases(), |inst| {
        let mut reg = ResourceRegistry::new();
        let cpu = reg.register("cpu", ResourceClass::CpuCores, inst.units);
        let actions: Vec<Action> = inst
            .actions
            .iter()
            .enumerate()
            .map(|(i, &(min, max, dur, scalable))| {
                Action::new(
                    ActionId(i as u64),
                    ActionSpec {
                        task: TaskId(0),
                        tenant: TenantId(0),
                        trajectory: TrajId(i as u64),
                        kind: ActionKind::RewardCpu,
                        cost: CostSpec::single(
                            &reg,
                            cpu,
                            if max > min {
                                DimCost::Range { min, max }
                            } else {
                                DimCost::Fixed(min)
                            },
                        ),
                        key_resource: Some(cpu),
                        elasticity: if scalable {
                            ElasticityModel::Amdahl { serial_frac: 0.1 }
                        } else {
                            ElasticityModel::None
                        },
                        profiled_dur: Some(SimDur::from_secs(dur)),
                        service: None,
                        true_dur: SimDur::from_secs(dur),
                    },
                    SimTime::ZERO,
                )
            })
            .collect();
        let refs: Vec<&Action> = actions.iter().collect();
        let pool = FlatPool(inst.units);
        let mut map = ResourceMap::new();
        map.insert(cpu, &pool);
        let sched = ElasticScheduler::new(SchedulerConfig::default());
        let decisions = sched.schedule(SimTime::ZERO, &refs, &map);

        let mut total = 0u64;
        let mut seen = std::collections::HashSet::new();
        for d in &decisions {
            if !seen.insert(d.action) {
                return Err(format!("duplicate decision for {:?}", d.action));
            }
            let a = &actions[d.action.0 as usize];
            let dim = a.spec.cost.dim(cpu);
            if !dim.allows(d.units) {
                return Err(format!("units {} not allowed by {:?}", d.units, dim));
            }
            total += d.units;
        }
        if total > inst.units {
            return Err(format!("allocated {total} > capacity {}", inst.units));
        }
        // NOTE: an empty decision set is legal — greedy eviction may choose
        // to *wait* for more capacity (paper Alg. 1 with t = |C_j|); the
        // coordinator's liveness guard handles the idle-pool case and is
        // covered by the system-integration tests.
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// basic manager invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_basic_manager_respects_limits() {
    struct OpsGen;
    impl Gen for OpsGen {
        type Value = Vec<(bool, u64)>;
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (0..rng.range(1, 40))
                .map(|_| (rng.chance(0.6), rng.range(1, 3)))
                .collect()
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            if v.len() > 1 {
                vec![v[..v.len() / 2].to_vec()]
            } else {
                vec![]
            }
        }
    }
    check("basic limit", &OpsGen, default_cases(), |ops| {
        let limit = 8;
        let mut m = BasicManager::concurrency("t", limit);
        let mut live: Vec<(ActionId, u64)> = vec![];
        for (i, &(is_alloc, units)) in ops.iter().enumerate() {
            if is_alloc {
                let id = ActionId(i as u64);
                if m.allocate(id, units, SimTime(i as u64)).is_ok() {
                    live.push((id, units));
                }
            } else if !live.is_empty() {
                let (id, u) = live.remove(0);
                m.complete(id, u);
            }
            let total: u64 = live.iter().map(|(_, u)| u).sum();
            if m.in_flight() != total {
                return Err(format!("in_flight {} != live {total}", m.in_flight()));
            }
            if total > limit {
                return Err(format!("limit violated: {total} > {limit}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// DES engine monotonicity
// ---------------------------------------------------------------------------

#[test]
fn prop_des_time_is_monotone() {
    struct StormGen;
    impl Gen for StormGen {
        type Value = Vec<u64>;
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (0..rng.range(1, 200)).map(|_| rng.range(0, 1000)).collect()
        }
    }
    check("des monotone", &StormGen, default_cases(), |delays| {
        let mut eng: Engine<usize> = Engine::new();
        for (i, &d) in delays.iter().enumerate() {
            eng.schedule_at(SimTime(d), i);
        }
        let mut last = SimTime::ZERO;
        let mut n = 0;
        while let Some((t, _)) = eng.next() {
            if t < last {
                return Err(format!("time regressed {t:?} < {last:?}"));
            }
            last = t;
            n += 1;
        }
        if n != delays.len() {
            return Err(format!("lost events: {n} of {}", delays.len()));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// CPU manager conservation under random trajectories
// ---------------------------------------------------------------------------

#[test]
fn prop_cpu_manager_conserves_cores_and_memory() {
    struct TrajGen;
    impl Gen for TrajGen {
        type Value = Vec<(u64, u32, u64)>;
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (0..rng.range(1, 30))
                .map(|i| (i, rng.range(1, 8) as u32, rng.range(1, 16)))
                .collect()
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            if v.len() > 1 {
                vec![v[..v.len() / 2].to_vec()]
            } else {
                vec![]
            }
        }
    }
    check("cpu conservation", &TrajGen, default_cases(), |trajs| {
        let mut m = CpuManager::new(2, 2, 8, 64, CpuLatency::default());
        let total_cores = m.total_cores();
        let mut active = vec![];
        for &(t, cores, mem) in trajs {
            let traj = TrajId(t);
            if m.bind_trajectory(traj, cores, mem).is_ok() {
                if m.allocate(ActionId(t), traj, cores, true, SimTime(t)).is_ok() {
                    active.push((ActionId(t), traj));
                }
            }
        }
        let leased: u64 = total_cores - m.free_cores();
        if leased > total_cores {
            return Err("core accounting underflow".into());
        }
        for (a, t) in active {
            m.complete(a).map_err(|e| e.to_string())?;
            m.release_trajectory(t).map_err(|e| e.to_string())?;
        }
        if m.free_cores() != total_cores {
            return Err(format!("cores leaked: {} != {total_cores}", m.free_cores()));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// lanes::CostModel — rate cards and dollar-weighted savings
// ---------------------------------------------------------------------------

/// Rates drawn from an eighths menu: every product and partial sum in the
/// resolution arithmetic is exactly representable, so the order-independence
/// and sum-agreement properties below can assert *bitwise* f64 equality.
const RATE_MENU: [f64; 6] = [0.125, 0.25, 0.5, 1.0, 2.5, 4.0];

#[derive(Debug, Clone)]
struct CostCase {
    rates: Vec<(String, f64)>,
    default_rate: f64,
    /// Synthetic provision series: (pool, at secs, units).
    provision: Vec<(String, u64, u64)>,
}

struct CostGen;

impl Gen for CostGen {
    type Value = CostCase;
    fn generate(&self, rng: &mut Rng) -> CostCase {
        let mut rates = Vec::new();
        for pool in ["cpu_cores", "gpus", "api_lanes"] {
            if rng.chance(0.7) {
                rates.push((pool.to_string(), *rng.pick(&RATE_MENU)));
            }
        }
        for e in 0..rng.range(0, 2) {
            rates.push((format!("api_lanes@{e}"), *rng.pick(&RATE_MENU)));
        }
        let mut provision = Vec::new();
        for pool in ["cpu_cores", "gpus", "api_lanes"] {
            let mut at = 0;
            for _ in 0..rng.range(1, 5) {
                provision.push((pool.to_string(), at, rng.range(1, 64)));
                at += rng.range(1, 50);
            }
        }
        CostCase { rates, default_rate: *rng.pick(&RATE_MENU), provision }
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if !v.rates.is_empty() {
            out.push(CostCase { rates: Vec::new(), ..v.clone() });
        }
        if v.provision.len() > 1 {
            let half = v.provision[..v.provision.len() / 2].to_vec();
            out.push(CostCase { provision: half, ..v.clone() });
        }
        out
    }
}

fn cost_model_of(case: &CostCase) -> CostModel {
    let mut rates = BTreeMap::new();
    for (k, r) in &case.rates {
        rates.insert(k.clone(), *r);
    }
    CostModel { rates, default_rate: case.default_rate }
}

fn metrics_of(case: &CostCase, rates: BTreeMap<String, f64>) -> Metrics {
    let mut m = Metrics::default();
    for (pool, at, units) in &case.provision {
        m.provision.push(ProvisionRecord {
            at: SimTime(SimDur::from_secs(*at).0),
            pool: pool.clone(),
            units: *units,
        });
    }
    m.cost_rates = Some(rates);
    m
}

#[test]
fn prop_cost_rows_sum_to_pool_cost() {
    check("cost rows = pool_cost", &CostGen, default_cases(), |case| {
        let model = cost_model_of(case);
        let mut resolved = BTreeMap::new();
        for pool in ["cpu_cores", "gpus", "api_lanes"] {
            resolved.insert(pool.to_string(), model.rate_for(pool, None));
        }
        let m = metrics_of(case, resolved);
        let rows = m.cost_rows();
        if rows.len() != 3 {
            return Err(format!("expected 3 cost rows, got {}", rows.len()));
        }
        let (mut used_sum, mut stat_sum) = (0.0, 0.0);
        for (pool, rate, used, stat) in &rows {
            let (pu, ps) = m.pool_cost(pool);
            if *used != pu || *stat != ps {
                return Err(format!("row for '{pool}' != pool_cost: {used}/{stat} vs {pu}/{ps}"));
            }
            if *rate <= 0.0 {
                return Err(format!("non-positive rate {rate} for '{pool}'"));
            }
            used_sum += *used;
            stat_sum += *stat;
        }
        let savings = Metrics::cost_savings_of(&rows);
        let direct = if stat_sum <= 0.0 { 0.0 } else { 1.0 - used_sum / stat_sum };
        if (savings - direct).abs() > 1e-12 {
            return Err(format!("cost_savings_of {savings} != recomputed {direct}"));
        }
        Ok(())
    });
}

#[test]
fn prop_endpoint_resolution_order_independent() {
    check("resolve order-independent", &CostGen, default_cases(), |case| {
        let model = cost_model_of(case);
        let pressure = |endpoint: Option<u32>, baseline: u64| PoolPressure {
            key: LaneKey {
                class: if endpoint.is_some() { PoolClass::Api } else { PoolClass::Cpu },
                endpoint,
            },
            queued: 0,
            queued_units: 0,
            in_use_units: 0,
            provisioned_units: baseline,
            baseline_units: baseline,
        };
        let mut pressures = vec![
            pressure(None, 64),
            pressure(Some(0), 7),
            pressure(Some(1), 13),
            pressure(Some(2), 41),
        ];
        let provisioned = vec![
            ("cpu_cores".to_string(), 64u64),
            ("gpus".to_string(), 16u64),
            ("api_lanes".to_string(), 61u64),
        ];
        let forward = model.resolve(&pressures, &provisioned);
        pressures.reverse();
        let backward = model.resolve(&pressures, &provisioned);
        pressures.rotate_left(1);
        let rotated = model.resolve(&pressures, &provisioned);
        if forward != backward || forward != rotated {
            return Err(format!("resolution order-dependent: {forward:?} vs {backward:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_uniform_card_savings_sign_agrees() {
    check("uniform card sign", &CostGen, default_cases(), |case| {
        let rate = case.default_rate;
        let mut uniform = BTreeMap::new();
        for pool in ["cpu_cores", "gpus", "api_lanes"] {
            uniform.insert(pool.to_string(), rate);
        }
        let m = metrics_of(case, uniform);
        let weighted = m.savings_vs_static_cost();
        let unweighted = m.savings_vs_static();
        if (weighted - unweighted).abs() > 1e-9 {
            return Err(format!("uniform card diverged: {weighted} vs {unweighted}"));
        }
        if weighted.abs() > 1e-9 && weighted.signum() != unweighted.signum() {
            return Err(format!("savings signs disagree: {weighted} vs {unweighted}"));
        }
        Ok(())
    });
}
