//! Integration tests over the real AOT artifacts: load HLO text, compile on
//! the PJRT CPU client, run init/forward/train — the full L2↔L3 bridge.
//!
//! Requires `make artifacts` (skipped gracefully if absent so unit-test runs
//! don't depend on Python) and a build with the real PJRT runtime
//! (`RUSTFLAGS="--cfg arl_pjrt"`); the default zero-dependency build
//! compiles this file to an empty test target. The PJRT client is
//! `Rc`-based (not `Send`), and compiling the six artifacts takes tens of
//! seconds, so all checks share one engine inside a single #[test].

#![cfg(arl_pjrt)]

use arl_tangram::runtime::{PjrtEngine, RewardModel, Trainer};

#[test]
fn runtime_end_to_end() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("meta.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let eng = PjrtEngine::load(dir).expect("engine load");

    // -- artifacts load and compile --------------------------------------
    assert_eq!(eng.platform().to_lowercase(), "cpu");
    for name in [
        "policy_init",
        "policy_fwd",
        "policy_logprobs",
        "train_step",
        "reward_init",
        "reward_fwd",
    ] {
        assert!(eng.has(name), "missing artifact {name}");
    }

    // -- policy init determinism + logits shape --------------------------
    let t1 = Trainer::init(&eng, 1234).unwrap();
    let t2 = Trainer::init(&eng, 1234).unwrap();
    let ones = vec![1i32; t1.batch * t1.seq];
    let l1 = t1.logits(&ones).unwrap();
    let l2 = t2.logits(&ones).unwrap();
    assert_eq!(l1.len(), t1.batch * t1.seq * t1.vocab);
    assert_eq!(l1, l2, "same seed must give identical params");
    let t3 = Trainer::init(&eng, 999).unwrap();
    assert_ne!(l1, t3.logits(&ones).unwrap(), "different seed must differ");

    // -- logprobs sane ----------------------------------------------------
    let toks_mod: Vec<i32> = (0..t1.batch * t1.seq).map(|i| (i % 100) as i32).collect();
    let lp = t1.logprobs(&toks_mod).unwrap();
    assert_eq!(lp.len(), t1.batch * (t1.seq - 1));
    assert!(lp.iter().all(|&x| x.is_finite() && x <= 1e-4), "bad logprobs");

    // -- GRPO train step moves logprobs in the advantage direction -------
    let mut tr = Trainer::init(&eng, 42).unwrap();
    let (b, s) = (tr.batch, tr.seq);
    let tokens: Vec<i32> = (0..b * s).map(|i| ((i * 7) % 50) as i32).collect();
    let mask = vec![1f32; b * (s - 1)];
    let adv: Vec<f32> = (0..b).map(|i| if i < b / 2 { 1.0 } else { -1.0 }).collect();
    let lp0 = tr.logprobs(&tokens).unwrap();
    let sum0: f32 = lp0[..s - 1].iter().sum();
    for step in 1..=4 {
        let old = tr.logprobs(&tokens).unwrap();
        let loss = tr.train_step(&tokens, &mask, &adv, &old, 3e-4).unwrap();
        assert!(loss.is_finite(), "loss {loss} at step {step}");
        assert_eq!(tr.step_count().unwrap(), step);
    }
    let lp1 = tr.logprobs(&tokens).unwrap();
    let sum1: f32 = lp1[..s - 1].iter().sum();
    assert!(
        sum1 > sum0,
        "positively-advantaged sequence logprob should rise: {sum0} -> {sum1}"
    );

    // -- reward model -----------------------------------------------------
    let rm = RewardModel::init(&eng, 5).unwrap();
    let rt: Vec<i32> = (0..rm.batch * rm.seq).map(|i| (i % 64) as i32).collect();
    let rmask = vec![1f32; rm.batch * rm.seq];
    let scores = rm.score(&rt, &rmask).unwrap();
    assert_eq!(scores.len(), rm.batch);
    assert!(scores.iter().all(|s| s.abs() < 1.0 && s.is_finite()));
    assert_eq!(scores, rm.score(&rt, &rmask).unwrap(), "deterministic");
}
