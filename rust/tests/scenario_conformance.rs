//! Differential conformance suite: every built-in scenario pack × every
//! backend, each run twice through the trace recorder, asserting that
//! metrics summaries and decision traces are **byte-identical** and that
//! the runs complete with full accounting. This is the quality ratchet for
//! scheduler changes: any nondeterminism or behavioural drift shows up as
//! a trace divergence here before it can corrupt an experiment.

use arl_tangram::config::BackendKind;
use arl_tangram::scenario::{
    builtin_packs, diff_traces, pack_by_name, run_scenario, summary_json, trace_file_contents,
    ScenarioSpec, TraceKind,
};

fn expected_trajectories(spec: &ScenarioSpec, backend: BackendKind) -> usize {
    spec.workloads_for(backend).len() * spec.batch * spec.steps as usize
}

#[test]
fn every_pack_replays_byte_identically_on_every_backend() {
    let mut combos = 0usize;
    let mut per_backend = std::collections::HashMap::new();
    for spec in builtin_packs() {
        let mut backends_run = 0usize;
        for backend in BackendKind::ALL {
            if spec.workloads_for(backend).is_empty() {
                continue; // single-purpose baseline: unsupported mix subset
            }
            let first = run_scenario(&spec, backend).unwrap();
            let second = run_scenario(&spec, backend).unwrap();

            // differential check: byte-identical summaries…
            let s1 = summary_json(&first.metrics).to_string();
            let s2 = summary_json(&second.metrics).to_string();
            assert_eq!(s1, s2, "summary diverged: '{}' on {:?}", spec.name, backend);
            // …and identical decision traces
            let div = diff_traces(&first.events, &second.events, 5);
            assert!(
                div.is_empty(),
                "trace diverged: '{}' on {:?}: {div:?}",
                spec.name,
                backend
            );

            // completion accounting
            assert_eq!(
                first.metrics.trajectories.len(),
                expected_trajectories(&spec, backend),
                "'{}' on {:?} lost trajectories",
                spec.name,
                backend
            );
            assert!(!first.events.is_empty());
            // every injection in the spec must appear in the trace
            let injected = first
                .events
                .iter()
                .filter(|e| matches!(e.kind, TraceKind::Inject { .. }))
                .count();
            assert_eq!(
                injected,
                spec.events.len(),
                "'{}' on {:?} dropped injections",
                spec.name,
                backend
            );

            combos += 1;
            backends_run += 1;
            *per_backend.entry(backend.name()).or_insert(0usize) += 1;
        }
        assert!(
            backends_run >= 2,
            "pack '{}' must exercise at least two backends",
            spec.name
        );
    }
    // acceptance floor: ≥4 packs × all 4 execution backends (the PR-3 packs
    // raised every backend's coverage)
    for backend in ["tangram", "k8s", "static", "serverless"] {
        assert!(
            per_backend.get(backend).copied().unwrap_or(0) >= 4,
            "backend {backend} covered by {:?} pack-combos",
            per_backend.get(backend)
        );
    }
    // 11 packs (the two tenant-mix packs joined the catalog) over their
    // supported backends
    assert!(combos >= 40, "only {combos} pack×backend combos ran");
}

#[test]
fn recorded_trace_file_round_trips_and_replays() {
    use arl_tangram::scenario::{parse_trace_file, replay_trace};
    let spec = pack_by_name("restore-storm").unwrap();
    let outcome = run_scenario(&spec, BackendKind::Tangram).unwrap();
    let text = trace_file_contents(&spec, BackendKind::Tangram, &outcome);
    let recorded = parse_trace_file(&text).unwrap();
    let report = replay_trace(&recorded).unwrap();
    assert!(
        report.identical,
        "record→replay must be byte-identical: {:?} {:?}",
        report.summary_diff, report.trace_divergences
    );
}

#[test]
fn injections_change_behaviour_on_tangram() {
    // The fault timeline must actually bite: the restore-storm pack has to
    // produce strictly more restore overhead than the same spec without its
    // events, and the api-flap pack must inflate API queueing on DeepSearch.
    use arl_tangram::action::ActionKind;
    let reward_overhead_secs = |m: &arl_tangram::metrics::Metrics| -> f64 {
        m.actions
            .iter()
            .filter(|a| a.kind == ActionKind::RewardModel)
            .map(|a| a.overhead.secs_f64())
            .sum()
    };
    let api_queue_secs = |m: &arl_tangram::metrics::Metrics| -> f64 {
        m.actions
            .iter()
            .filter(|a| a.kind == ActionKind::ApiCall)
            .map(|a| a.queue_dur().secs_f64())
            .sum()
    };

    let storm = pack_by_name("restore-storm").unwrap();
    let mut calm = storm.clone();
    calm.events.clear();
    let with = run_scenario(&storm, BackendKind::Tangram).unwrap();
    let without = run_scenario(&calm, BackendKind::Tangram).unwrap();
    assert!(
        reward_overhead_secs(&with.metrics) > reward_overhead_secs(&without.metrics),
        "cache flushes must raise restore overhead: {} !> {}",
        reward_overhead_secs(&with.metrics),
        reward_overhead_secs(&without.metrics)
    );

    let flap = pack_by_name("api-flap").unwrap();
    let mut steady = flap.clone();
    steady.events.clear();
    let with = run_scenario(&flap, BackendKind::Tangram).unwrap();
    let without = run_scenario(&steady, BackendKind::Tangram).unwrap();
    assert!(
        api_queue_secs(&with.metrics) > api_queue_secs(&without.metrics),
        "quota flaps must inflate API queueing: {} !> {}",
        api_queue_secs(&with.metrics),
        api_queue_secs(&without.metrics)
    );
}

#[test]
fn cpu_pool_squeeze_applies_and_recovers() {
    let spec = pack_by_name("pool-squeeze").unwrap();
    let outcome = run_scenario(&spec, BackendKind::Tangram).unwrap();
    // both injections delivered and applied by the tangram backend
    let applied: Vec<bool> = outcome
        .events
        .iter()
        .filter_map(|e| match &e.kind {
            TraceKind::Inject { applied, .. } => Some(*applied),
            _ => None,
        })
        .collect();
    assert_eq!(applied, vec![true, true]);
    // the run still completes every trajectory despite the squeeze
    assert_eq!(
        outcome.metrics.trajectories.len(),
        expected_trajectories(&spec, BackendKind::Tangram)
    );
    assert_eq!(outcome.metrics.failed_actions(), 0);
}

#[test]
fn spec_files_round_trip_through_json() {
    for spec in builtin_packs() {
        let text = spec.to_json().to_string();
        let back = ScenarioSpec::from_json(&text).unwrap();
        assert_eq!(back.to_json().to_string(), text);
    }
}

#[test]
fn coldstart_storm_flushes_bite_and_multi_step_completes() {
    // Two RL steps with cache-flush storms: tangram must complete every
    // trajectory of both steps and the flushes must raise GPU restore
    // overhead vs the same spec without them.
    use arl_tangram::action::ActionKind;
    let storm = pack_by_name("coldstart-storm").unwrap();
    assert_eq!(storm.steps, 2, "coldstart-storm is a multi-step pack");
    let mut calm = storm.clone();
    calm.events.clear();
    let with = run_scenario(&storm, BackendKind::Tangram).unwrap();
    let without = run_scenario(&calm, BackendKind::Tangram).unwrap();
    assert_eq!(
        with.metrics.trajectories.len(),
        expected_trajectories(&storm, BackendKind::Tangram)
    );
    let restore = |m: &arl_tangram::metrics::Metrics| -> f64 {
        m.actions
            .iter()
            .filter(|a| a.kind == ActionKind::RewardModel)
            .map(|a| a.overhead.secs_f64())
            .sum()
    };
    assert!(
        restore(&with.metrics) > restore(&without.metrics),
        "cold-start storm must raise restore overhead: {} !> {}",
        restore(&with.metrics),
        restore(&without.metrics)
    );
}

#[test]
fn teacher_sweep_multiplexes_the_larger_fleet() {
    // Eight teachers on a pool that cannot pin them all resident: tangram
    // must still complete, and the trace must touch every teacher service.
    let spec = pack_by_name("teacher-sweep").unwrap();
    assert_eq!(spec.catalog.n_teachers, 8);
    let outcome = run_scenario(&spec, BackendKind::Tangram).unwrap();
    assert_eq!(
        outcome.metrics.trajectories.len(),
        expected_trajectories(&spec, BackendKind::Tangram)
    );
    let rm_actions = outcome
        .metrics
        .actions
        .iter()
        .filter(|a| a.kind == arl_tangram::action::ActionKind::RewardModel)
        .count();
    assert!(rm_actions >= spec.batch, "teacher fleet barely exercised: {rm_actions}");
}

#[test]
fn gpu_thrash_squeezes_and_recovers_the_gpu_pool() {
    // The GPU pool-squeeze mirror of pool-squeeze: every flush and
    // gpu_pool_scale injection must apply on tangram, the run completes
    // every trajectory across both steps, and the flush storm raises
    // restore overhead vs the same spec without events.
    use arl_tangram::action::ActionKind;
    let spec = pack_by_name("gpu-thrash").unwrap();
    assert_eq!(spec.steps, 2, "gpu-thrash is a multi-step pack");
    let outcome = run_scenario(&spec, BackendKind::Tangram).unwrap();
    let applied: Vec<bool> = outcome
        .events
        .iter()
        .filter_map(|e| match &e.kind {
            TraceKind::Inject { applied, .. } => Some(*applied),
            _ => None,
        })
        .collect();
    assert_eq!(applied.len(), spec.events.len());
    assert!(applied.iter().all(|&a| a), "tangram must honor flushes and GPU squeezes");
    assert_eq!(
        outcome.metrics.trajectories.len(),
        expected_trajectories(&spec, BackendKind::Tangram)
    );
    assert_eq!(outcome.metrics.failed_actions(), 0);
    let mut calm = spec.clone();
    calm.events.clear();
    let without = run_scenario(&calm, BackendKind::Tangram).unwrap();
    let restore = |m: &arl_tangram::metrics::Metrics| -> f64 {
        m.actions
            .iter()
            .filter(|a| a.kind == ActionKind::RewardModel)
            .map(|a| a.overhead.secs_f64())
            .sum()
    };
    assert!(
        restore(&outcome.metrics) > restore(&without.metrics),
        "thrash must raise restore overhead: {} !> {}",
        restore(&outcome.metrics),
        restore(&without.metrics)
    );
}

#[test]
fn flap_squeeze_applies_every_injection_on_tangram() {
    let spec = pack_by_name("flap-squeeze").unwrap();
    assert_eq!(spec.steps, 2, "flap-squeeze composes faults across steps");
    let outcome = run_scenario(&spec, BackendKind::Tangram).unwrap();
    let applied: Vec<bool> = outcome
        .events
        .iter()
        .filter_map(|e| match &e.kind {
            TraceKind::Inject { applied, .. } => Some(*applied),
            _ => None,
        })
        .collect();
    assert_eq!(applied.len(), spec.events.len());
    assert!(applied.iter().all(|&a| a), "tangram must honor flaps and squeezes");
    assert_eq!(
        outcome.metrics.trajectories.len(),
        expected_trajectories(&spec, BackendKind::Tangram)
    );
}
