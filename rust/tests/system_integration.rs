//! Whole-system integration: all workloads × all backends through the DES
//! driver, checking cross-cutting invariants (completion, conservation,
//! determinism, metric sanity) rather than point behaviours.

use arl_tangram::action::TaskId;
use arl_tangram::baselines::{BaselineBackend, K8sCfg, ServerlessCfg};
use arl_tangram::coordinator::{run, Backend, RunCfg, TangramBackend, TangramCfg};
use arl_tangram::metrics::Metrics;
use arl_tangram::rollout::workloads::{Catalog, CatalogCfg, Workload, WorkloadKind};

fn cat() -> Catalog {
    Catalog::build(&CatalogCfg {
        cpu_nodes: 2,
        cores_per_node: 64,
        gpu_nodes: 2,
        n_teachers: 4,
        ..CatalogCfg::default()
    })
}

fn tangram(c: &Catalog) -> TangramBackend {
    TangramBackend::new(
        c,
        TangramCfg {
            cpu_nodes: 2,
            numa_per_node: 2,
            cores_per_numa: 32,
            node_mem_gb: 512,
            gpu_nodes: 2,
            ..TangramCfg::default()
        },
    )
}

fn check_invariants(m: &Metrics, expect_traj: usize) {
    assert_eq!(m.trajectories.len(), expect_traj, "all trajectories accounted");
    for a in &m.actions {
        assert!(a.started >= a.submitted, "causality: {a:?}");
        assert!(a.finished >= a.started, "causality: {a:?}");
    }
    for t in &m.trajectories {
        assert!(t.finished >= t.started);
        assert!(t.active_ratio() <= 1.0 + 1e-9);
    }
    assert!(m.mean_act() >= 0.0);
    assert!(m.mean_step_dur() > 0.0);
}

#[test]
fn every_workload_completes_on_tangram() {
    let c = cat();
    for kind in [WorkloadKind::Coding, WorkloadKind::DeepSearch, WorkloadKind::Mopd] {
        let mut be = tangram(&c);
        let wl = Workload::new(TaskId(0), kind);
        let cfg = RunCfg { batch: 12, steps: 2, seed: 99, ..RunCfg::default() };
        let m = run(&mut be, &c, &[wl], &cfg);
        check_invariants(&m, 24);
        // the cluster must drain completely
        assert_eq!(be.cpu.free_cores(), be.cpu.total_cores(), "{kind:?}");
        assert_eq!(be.gpu.free_gpus(), be.gpu.total_gpus(), "{kind:?}");
    }
}

#[test]
fn every_baseline_completes_its_workload() {
    let c = cat();
    let cfg = RunCfg { batch: 10, steps: 1, seed: 7, ..RunCfg::default() };
    let cases: Vec<(Box<dyn Backend>, WorkloadKind)> = vec![
        (
            Box::new(BaselineBackend::coding(
                &c,
                K8sCfg { nodes: 2, cores_per_node: 64, node_mem_gb: 512, ..K8sCfg::default() },
            )),
            WorkloadKind::Coding,
        ),
        (Box::new(BaselineBackend::mopd(&c)), WorkloadKind::Mopd),
        (Box::new(BaselineBackend::deepsearch(&c)), WorkloadKind::DeepSearch),
        (
            Box::new(BaselineBackend::serverless(
                &c,
                ServerlessCfg { gpu_nodes: 2, ..ServerlessCfg::default() },
            )),
            WorkloadKind::Mopd,
        ),
    ];
    for (mut be, kind) in cases {
        let wl = Workload::new(TaskId(0), kind);
        let m = run(be.as_mut(), &c, &[wl], &cfg);
        check_invariants(&m, 10);
    }
}

#[test]
fn tangram_beats_k8s_on_coding_at_contention() {
    // the headline CPU claim, at a contention ratio near the paper's
    let c = Catalog::build(&CatalogCfg {
        cpu_nodes: 2,
        cores_per_node: 128,
        ..CatalogCfg::default()
    });
    let mut t = TangramBackend::new(
        &c,
        TangramCfg {
            cpu_nodes: 2,
            numa_per_node: 2,
            cores_per_numa: 64,
            ..TangramCfg::default()
        },
    );
    let wl = Workload::new(TaskId(0), WorkloadKind::Coding);
    let cfg = RunCfg { batch: 256, steps: 1, seed: 31, ..RunCfg::default() };
    let mt = run(&mut t, &c, &[wl.clone()], &cfg);
    let mut k = BaselineBackend::coding(
        &c,
        K8sCfg { nodes: 2, cores_per_node: 128, ..K8sCfg::default() },
    );
    let mk = run(&mut k, &c, &[wl], &cfg);
    assert!(
        mt.mean_act() < mk.mean_act(),
        "tangram {:.2}s !< k8s {:.2}s",
        mt.mean_act(),
        mk.mean_act()
    );
}

#[test]
fn failure_injection_unmanaged_api_storms_recover() {
    // the unmanaged baseline must survive its own retry storms (trajectories
    // restart; the run still terminates with full accounting)
    let c = cat();
    let mut be = BaselineBackend::deepsearch(&c);
    let wl = Workload::new(TaskId(0), WorkloadKind::DeepSearch);
    let cfg = RunCfg { batch: 64, steps: 1, seed: 13, max_traj_restarts: 2, ..RunCfg::default() };
    let m = run(&mut be, &c, &[wl], &cfg);
    check_invariants(&m, 64);
    assert!(m.total_retries() > 0, "storm expected");
    let (_ok, limited, to, err) = be.api.as_ref().unwrap().failure_counts();
    assert!(limited + to + err > 0, "provider should have shed or failed some load");
}

#[test]
fn determinism_two_same_seed_runs_serialize_byte_identically() {
    // Locks in the sim engine's tie-break-by-seq guarantee at system level:
    // for every workload × backend composition, two same-seed runs must
    // produce byte-identical serialized Metrics JSON. This is what makes
    // the scenario record/replay harness able to byte-diff runs across
    // processes (all decision paths iterate pools in sorted order).
    let c = cat();
    type Mk = Box<dyn Fn(&Catalog) -> Box<dyn Backend>>;
    let cases: Vec<(Mk, WorkloadKind, &str)> = vec![
        (Box::new(|c: &Catalog| Box::new(tangram(c)) as Box<dyn Backend>), WorkloadKind::Coding, "tangram/coding"),
        (Box::new(|c: &Catalog| Box::new(tangram(c)) as Box<dyn Backend>), WorkloadKind::DeepSearch, "tangram/deepsearch"),
        (Box::new(|c: &Catalog| Box::new(tangram(c)) as Box<dyn Backend>), WorkloadKind::Mopd, "tangram/mopd"),
        (
            Box::new(|c: &Catalog| {
                Box::new(BaselineBackend::coding(
                    c,
                    K8sCfg { nodes: 2, cores_per_node: 64, node_mem_gb: 512, ..K8sCfg::default() },
                )) as Box<dyn Backend>
            }),
            WorkloadKind::Coding,
            "k8s/coding",
        ),
        (
            Box::new(|c: &Catalog| Box::new(BaselineBackend::mopd_search(c)) as Box<dyn Backend>),
            WorkloadKind::Mopd,
            "static/mopd",
        ),
        (
            Box::new(|c: &Catalog| Box::new(BaselineBackend::mopd_search(c)) as Box<dyn Backend>),
            WorkloadKind::DeepSearch,
            "static/deepsearch",
        ),
        (
            Box::new(|c: &Catalog| {
                Box::new(BaselineBackend::serverless(
                    c,
                    ServerlessCfg { gpu_nodes: 2, ..ServerlessCfg::default() },
                )) as Box<dyn Backend>
            }),
            WorkloadKind::Mopd,
            "serverless/mopd",
        ),
        (
            Box::new(|c: &Catalog| Box::new(BaselineBackend::deepsearch(c)) as Box<dyn Backend>),
            WorkloadKind::DeepSearch,
            "unmanaged/deepsearch",
        ),
    ];
    for (mk, kind, label) in cases {
        let cfg = RunCfg { batch: 8, steps: 1, seed: 71, ..RunCfg::default() };
        let wl = Workload::new(TaskId(0), kind);
        let m1 = run(mk(&c).as_mut(), &c, &[wl.clone()], &cfg);
        let m2 = run(mk(&c).as_mut(), &c, &[wl], &cfg);
        assert_eq!(
            m1.to_json().to_string(),
            m2.to_json().to_string(),
            "metrics JSON diverged for {label}"
        );
    }
}

#[test]
fn config_driven_launch_matches_direct() {
    use arl_tangram::config::ExperimentCfg;
    let cfg = ExperimentCfg::from_json(
        r#"{"backend":"tangram","workloads":["mopd"],"batch":8,"steps":1,"seed":5,
            "cpu_nodes":2,"cores_per_node":64,"gpu_nodes":2,"n_teachers":4}"#,
    )
    .unwrap();
    let c = Catalog::build(&cfg.catalog);
    let mut be = TangramBackend::new(&c, cfg.tangram_cfg());
    let wl = Workload::new(TaskId(0), WorkloadKind::Mopd);
    let m = run(&mut be, &c, &[wl], &cfg.run);
    check_invariants(&m, 8);
}
