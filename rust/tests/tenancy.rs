//! Multi-tenant scheduling tests.
//!
//! Three pillars of the tenancy contract:
//!   1. conservation — per-tenant rollups sum field-by-field (bitwise, the
//!      sums are integer ns) to the global tallies on every built-in pack;
//!   2. the fairness differential — under lane WFQ a steady high-weight
//!      tenant keeps its mean ACT within 1.15× of its isolated-run value
//!      while a bursty co-tenant saturates the shared pool, and plain FCFS
//!      (tenancy-blind queues) demonstrably does NOT hold that bound;
//!   3. neutrality — on single-tenant runs WFQ order is indistinguishable
//!      from FCFS, byte-for-byte, so the redesign cannot perturb any
//!      pre-tenancy golden trace.

use arl_tangram::action::TenantId;
use arl_tangram::config::{BackendKind, ExperimentCfg};
use arl_tangram::coordinator::{run_session, Session, TangramBackend, TangramCfg};
use arl_tangram::metrics::TenantRollup;
use arl_tangram::rollout::workloads::Catalog;
use arl_tangram::scenario::{builtin_packs, pack_by_name, run_scenario, ScenarioSpec, TraceRecorder};

/// The same catalog→deployment scaling the scenario engine uses, plus the
/// FCFS knob for the differential arms.
fn tangram_cfg(spec: &ScenarioSpec, fcfs_queues: bool) -> TangramCfg {
    let exp = ExperimentCfg { catalog: spec.catalog.clone(), ..ExperimentCfg::default() };
    TangramCfg { fcfs_queues, ..exp.tangram_cfg() }
}

#[test]
fn tenant_rollups_sum_bitwise_to_global_on_every_pack() {
    for spec in builtin_packs() {
        let out = run_scenario(&spec, BackendKind::Tangram).unwrap();
        let m = &out.metrics;
        let mut sum = TenantRollup::default();
        for r in m.tenant_rollups().values() {
            sum.actions += r.actions;
            sum.failed += r.failed;
            sum.retries += r.retries;
            sum.act_ns += r.act_ns;
            sum.queue_ns += r.queue_ns;
        }
        assert_eq!(sum.actions, m.actions.len() as u64, "'{}': action count", spec.name);
        assert_eq!(sum.failed, m.failed_actions() as u64, "'{}': failed count", spec.name);
        assert_eq!(sum.retries, m.total_retries(), "'{}': retry count", spec.name);
        let global_act: u64 =
            m.actions.iter().filter(|a| !a.failed).map(|a| a.act().0).sum();
        let global_queue: u64 =
            m.actions.iter().filter(|a| !a.failed).map(|a| a.queue_dur().0).sum();
        assert_eq!(sum.act_ns, global_act, "'{}': summed ACT ns", spec.name);
        assert_eq!(sum.queue_ns, global_queue, "'{}': summed queue ns", spec.name);
    }
}

#[test]
fn tenant_packs_tag_every_declared_tenant() {
    for name in ["tenant-fairshare", "tenant-batch-interactive"] {
        let spec = pack_by_name(name).unwrap();
        let out = run_scenario(&spec, BackendKind::Tangram).unwrap();
        let rollups = out.metrics.tenant_rollups();
        let ids: Vec<u32> = rollups.keys().copied().collect();
        let declared: Vec<u32> = spec.tenants.iter().map(|t| t.id).collect();
        assert_eq!(ids, declared, "'{name}': rollup tenant ids");
        assert!(rollups.values().all(|r| r.actions > 0), "'{name}': idle tenant");
        assert!(out.metrics.multi_tenant(), "'{name}'");
    }
}

#[test]
fn wfq_protects_the_steady_tenant_where_fcfs_does_not() {
    let spec = pack_by_name("tenant-fairshare").unwrap();
    let cat = Catalog::build(&spec.catalog);
    let cfg = spec.run_cfg();
    let wls = spec.workloads_for(BackendKind::Tangram);
    let steady: Vec<_> =
        wls.iter().filter(|w| w.tenant == TenantId(0)).cloned().collect();
    assert!(!steady.is_empty() && steady.len() < wls.len());

    // isolated baseline: the steady tenant alone on the same deployment
    let mut be = TangramBackend::new(&cat, tangram_cfg(&spec, false));
    let mut session = Session::new();
    let iso = run_session(&mut be, &cat, &steady, &cfg, &mut session).mean_act();
    assert!(iso > 0.0);

    // shared pool under WFQ with the pack's 8:1 weights
    let mut be = TangramBackend::new(&cat, tangram_cfg(&spec, false));
    let mut session = Session::new().with_tenant_weights(spec.tenant_weights());
    let wfq = run_session(&mut be, &cat, &wls, &cfg, &mut session).mean_act_of_tenant(0);

    // shared pool under plain FCFS: tenancy-blind arrival-order queues
    let mut be = TangramBackend::new(&cat, tangram_cfg(&spec, true));
    let mut session = Session::new();
    let fcfs = run_session(&mut be, &cat, &wls, &cfg, &mut session).mean_act_of_tenant(0);

    assert!(
        wfq <= iso * 1.15,
        "WFQ failed to protect the steady tenant: shared {wfq:.2}s vs isolated {iso:.2}s"
    );
    assert!(
        fcfs > iso * 1.15,
        "FCFS held the fairness bound ({fcfs:.2}s vs isolated {iso:.2}s) — \
         the differential lost its teeth; deepen the bursty tenant"
    );
}

#[test]
fn single_tenant_wfq_is_byte_identical_to_fcfs() {
    // WFQ with one tenant degenerates to (finish-time, action-id) order ==
    // arrival order: flipping the queues to FCFS must not move a byte in
    // either the trace or the metrics of a faulted single-tenant pack.
    let spec = pack_by_name("pool-squeeze").unwrap();
    let cat = Catalog::build(&spec.catalog);
    let cfg = spec.run_cfg();
    let wls = spec.workloads_for(BackendKind::Tangram);
    let arm = |fcfs_queues: bool| {
        let mut be = TangramBackend::new(&cat, tangram_cfg(&spec, fcfs_queues));
        let mut session = Session::new()
            .with_injections(spec.events.clone())
            .with_recorder(TraceRecorder::new());
        let m = run_session(&mut be, &cat, &wls, &cfg, &mut session);
        let events = session.take_recorder().unwrap_or_default().events;
        let lines: Vec<String> = events.iter().map(|e| e.to_json().to_string()).collect();
        (m.to_json().to_string(), lines)
    };
    let (m_wfq, e_wfq) = arm(false);
    let (m_fcfs, e_fcfs) = arm(true);
    assert_eq!(m_wfq, m_fcfs, "metrics diverged between WFQ and FCFS");
    assert_eq!(e_wfq, e_fcfs, "trace diverged between WFQ and FCFS");
}

#[test]
fn tenant_weights_change_scheduling_but_conserve_work() {
    // Same multi-tenant pack, weights flipped from 8:1 to 1:8 — the traces
    // must differ (the weights are load-bearing) while the completed-work
    // totals stay identical (fairness redistributes waiting, never work).
    let spec = pack_by_name("tenant-fairshare").unwrap();
    let mut flipped = spec.clone();
    for t in &mut flipped.tenants {
        t.weight = if t.weight > 1 { 1 } else { 8 };
    }
    let a = run_scenario(&spec, BackendKind::Tangram).unwrap();
    let b = run_scenario(&flipped, BackendKind::Tangram).unwrap();
    assert_eq!(a.metrics.actions.len(), b.metrics.actions.len());
    assert_eq!(a.metrics.trajectories.len(), b.metrics.trajectories.len());
    assert_eq!(a.metrics.failed_actions(), b.metrics.failed_actions());
    let order = |events: &[arl_tangram::scenario::TraceEvent]| -> Vec<String> {
        events.iter().map(|e| e.to_json().to_string()).collect::<Vec<_>>()
    };
    assert_ne!(
        order(&a.events),
        order(&b.events),
        "flipping WFQ weights 8:1 → 1:8 left the trace untouched"
    );
    // and the steady tenant is strictly better off holding the high weight
    assert!(
        a.metrics.mean_act_of_tenant(0) < b.metrics.mean_act_of_tenant(0),
        "tenant 0 with weight 8 ({:.2}s) should beat tenant 0 with weight 1 ({:.2}s)",
        a.metrics.mean_act_of_tenant(0),
        b.metrics.mean_act_of_tenant(0)
    );
}
